"""Sharded mega-fleet solver: entry-axis partition (``repro.core.shard``)
vs the single-chip numpy driver, the jitted lowering path (replica
dedup, vectorized block fill, persistent program cache), and the
compile-stats surfacing on run results.

The acceptance bar is the ISSUE gate: sharded solves must match the
single-chip solve to 1e-12 *relative* across heterogeneous fleets, both
block layouts, with and without a ``comp0`` warm start — and a 1-shard
plan must fall back to the numpy driver bit-identically.  The mesh
(``shard_map``) executor runs in a subprocess with two forced virtual
host devices so the test works on any CI box.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    CompileStats, DeviceFleet, KiB, WorkloadSpec, ZnsDevice, ZNSDeviceSpec,
    clear_program_cache, clear_shard_plans, compile_fleet_program,
    extend_program, last_compile_stats, set_program_cache_dir, shard_program,
    solve_program, solve_program_sharded,
)
from repro.core import chain_program as cp
from strategies import HAVE_HYPOTHESIS

SPEC = ZNSDeviceSpec()


def _pool(threads=4, qd=2, n=80):
    wl = WorkloadSpec()
    for t in range(threads):
        wl = wl.appends(n=n, size=8 * KiB, qd=qd, zone=t * 4, nzones=4)
    return wl


def _tier_workloads():
    """Three heterogeneity tiers x two replicas each."""
    hard = _pool(threads=4, qd=2, n=60)
    med = WorkloadSpec().writes(n=200, qd=4, zone=7)
    easy = WorkloadSpec().reads(n=300, size=4 * KiB, qd=4, nzones=64)
    return [hard, hard, med, med, easy, easy]


def _fleet_program(workloads, *, cache=False, dedup=True):
    traces = [wl.build() for wl in workloads]
    devs = [ZnsDevice(SPEC) for _ in traces]
    return compile_fleet_program(traces, [d.spec for d in devs],
                                 [d.lat for d in devs], cache=cache,
                                 dedup=dedup)


def _assert_sharded_matches(prog, *, executor="host", comp0=None,
                            sweeps=64):
    ref, _, cv_ref = solve_program(prog, prog.svc0_flat, sweeps=sweeps,
                                   fixpoint="loop", comp0=comp0)
    got, _, cv = solve_program_sharded(prog, prog.svc0_flat, sweeps=sweeps,
                                       executor=executor, comp0=comp0)
    assert cv_ref and cv
    np.testing.assert_allclose(got, ref, rtol=1e-12, atol=0.0)
    return ref


# -- host executor: heterogeneous fleets, both layouts, warm starts ----------
def test_sharded_host_matches_single_chip_heterogeneous():
    prog = _fleet_program(_tier_workloads())
    ref = _assert_sharded_matches(prog)
    # warm start from the converged completions: still equal
    _assert_sharded_matches(prog, comp0=ref)
    # warm start from a strict lower bound (the issue+svc init itself)
    _assert_sharded_matches(prog, comp0=prog.issue_flat + prog.svc0_flat)


@pytest.mark.parametrize("layout", ["rows", "cols"])
def test_sharded_matches_on_forced_layout(layout, monkeypatch):
    if layout == "cols":
        monkeypatch.setattr(cp, "POSLOOP_MIN_CHAINS", 1)
        monkeypatch.setattr(cp, "POSLOOP_COST_CUTOVER", 0.0)
    else:
        monkeypatch.setattr(cp, "POSLOOP_MIN_CHAINS", 10**9)
    prog = _fleet_program(_tier_workloads())
    assert {b.layout for b in prog.families} == {layout}
    _assert_sharded_matches(prog)


def test_one_shard_plan_is_bit_identical():
    # a replicated fleet is one signature group -> the host plan has a
    # single shard and falls back to the plain numpy driver
    wl = _pool(threads=3, qd=2, n=60)
    prog = _fleet_program([wl, wl, wl])
    plan = shard_program(prog)
    assert plan.n_shards == 1
    ref, u_ref, _ = solve_program(prog, prog.svc0_flat, sweeps=32,
                                  fixpoint="loop")
    got, u_got, _ = solve_program_sharded(prog, prog.svc0_flat, sweeps=32,
                                          executor="host")
    assert np.array_equal(got, ref)          # bit-identical, not just close
    assert u_got == u_ref


def test_solve_program_routes_sharded_fixpoint():
    prog = _fleet_program(_tier_workloads())
    ref, _, _ = solve_program(prog, prog.svc0_flat, sweeps=64,
                              fixpoint="loop")
    got, _, cv = solve_program(prog, prog.svc0_flat, sweeps=64,
                               fixpoint="sharded")
    assert cv
    np.testing.assert_allclose(got, ref, rtol=1e-12, atol=0.0)


def test_sharded_validates_inputs():
    prog = _fleet_program([_pool(threads=2, qd=1, n=30)])
    with pytest.raises(ValueError):
        solve_program_sharded(prog, np.zeros(3))
    with pytest.raises(ValueError):
        solve_program_sharded(prog, prog.svc0_flat,
                              comp0=np.zeros(3))
    with pytest.raises(ValueError):
        solve_program_sharded(prog, prog.svc0_flat, executor="warp-drive")


# -- partition safety ---------------------------------------------------------
def test_shard_plan_balances_and_covers_entries():
    prog = _fleet_program(_tier_workloads())
    plan = shard_program(prog, n_shards=2)
    assert 1 <= plan.n_shards <= 2
    # the shard perms partition the flat event axis
    allp = np.sort(np.concatenate([s.perm for s in plan.shards]))
    assert np.array_equal(allp, np.arange(prog.n_flat))
    # signature grouping (host plan): replicas land in the same shard
    host = shard_program(prog)
    assert host.n_shards == 3                # one shard per tier
    for sh in host.shards:
        assert len(sh.devices) == 2


def test_cross_entry_chain_fuses_shards():
    prog = _fleet_program([_pool(threads=2, qd=1, n=30),
                           WorkloadSpec().reads(n=40, qd=2)])
    n0 = len(prog.orders[0])
    coupled = extend_program(
        prog, [("net_link", [np.asarray([n0 - 1, n0], dtype=np.int64)])])
    plan = shard_program(coupled, n_shards=2)
    assert plan.n_shards == 1                # union-find fused the entries
    assert plan.shards[0].devices == (0, 1)
    _assert_sharded_matches(coupled)


# -- mesh executor via forced virtual host devices (CI-runnable) -------------
MESH_SCRIPT = textwrap.dedent("""
    import os
    import numpy as np
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=2")
    import jax
    assert len(jax.local_devices()) == 2, jax.local_devices()
    from repro.core import (KiB, WorkloadSpec, ZnsDevice, ZNSDeviceSpec,
                            compile_fleet_program, solve_program,
                            solve_program_sharded)
    wl_a = WorkloadSpec()
    for t in range(3):
        wl_a = wl_a.appends(n=40, size=8 * KiB, qd=2, zone=t * 4, nzones=4)
    wl_b = WorkloadSpec().writes(n=120, qd=4, zone=7)
    wl_c = WorkloadSpec().reads(n=150, size=4 * KiB, qd=4, nzones=64)
    traces = [w.build() for w in (wl_a, wl_b, wl_c)]
    devs = [ZnsDevice(ZNSDeviceSpec()) for _ in traces]
    prog = compile_fleet_program(traces, [d.spec for d in devs],
                                 [d.lat for d in devs], cache=False)
    ref, _, cv_ref = solve_program(prog, prog.svc0_flat, sweeps=64,
                                   fixpoint="loop")
    got, _, cv = solve_program_sharded(prog, prog.svc0_flat, sweeps=64,
                                       executor="mesh")
    assert cv_ref and cv
    rel = np.max(np.abs(got - ref) / np.maximum(np.abs(ref), 1.0))
    assert rel <= 1e-12, rel
    # warm start down the same path
    got2, _, _ = solve_program_sharded(prog, prog.svc0_flat, sweeps=64,
                                       executor="mesh", comp0=ref)
    rel2 = np.max(np.abs(got2 - ref) / np.maximum(np.abs(ref), 1.0))
    assert rel2 <= 1e-12, rel2
    print("MESH_OK", rel, rel2)
""")


def test_mesh_executor_matches_loop_on_two_virtual_devices():
    pytest.importorskip("jax")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), os.pardir, "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MESH_OK" in proc.stdout


# -- jitted lowering path: dedup, vectorized fill, persistent cache ----------
def test_dedup_lowering_equivalent_and_counts_unique():
    wls = _tier_workloads()
    traces = [wl.build() for wl in wls]
    devs = [ZnsDevice(SPEC) for _ in traces]
    specs = [d.spec for d in devs]
    lats = [d.lat for d in devs]
    p_dd = compile_fleet_program(traces, specs, lats, cache=False,
                                 dedup=True)
    st = last_compile_stats()
    assert st.n_devices == 6 and st.n_unique == 3
    assert st.lowering_ms > 0.0
    p_ref = compile_fleet_program(traces, specs, lats, cache=False,
                                  dedup=False)
    assert last_compile_stats().n_unique == 6
    c1, _, _ = solve_program(p_dd, p_dd.svc0_flat, sweeps=64)
    c2, _, _ = solve_program(p_ref, p_ref.svc0_flat, sweeps=64)
    assert np.array_equal(c1, c2)


def test_vectorized_fill_matches_reference_fill(monkeypatch):
    wls = _tier_workloads()[:4]
    fast = _fleet_program(wls)
    monkeypatch.setattr(cp, "_USE_REFERENCE_FILL", True)
    slow = _fleet_program(wls)
    assert len(fast.families) == len(slow.families)
    for a, b in zip(fast.families, slow.families):
        assert a.label == b.label and a.layout == b.layout
        np.testing.assert_array_equal(a.gidx, b.gidx)
        np.testing.assert_array_equal(a.heads, b.heads)


def test_disk_program_cache_roundtrip(tmp_path):
    prev = set_program_cache_dir(str(tmp_path))
    try:
        clear_program_cache()
        traces = [wl.build() for wl in _tier_workloads()[:2]]
        devs = [ZnsDevice(SPEC) for _ in traces]
        specs, lats = [d.spec for d in devs], [d.lat for d in devs]
        p1 = compile_fleet_program(traces, specs, lats)
        assert last_compile_stats().misses == 1
        assert any(tmp_path.iterdir())        # program persisted
        # wipe the in-memory layers: the disk cache must serve the hit
        clear_program_cache()
        p2 = compile_fleet_program(traces, specs, lats)
        st = last_compile_stats()
        assert st.disk_hits == 1 and st.misses == 1 and st.hits == 0
        c1, _, _ = solve_program(p1, p1.svc0_flat, sweeps=32)
        c2, _, _ = solve_program(p2, p2.svc0_flat, sweeps=32)
        assert np.array_equal(c1, c2)
        # in-memory LRU now holds it: plain hit, no disk read
        p3 = compile_fleet_program(traces, specs, lats)
        assert last_compile_stats().hits == 1
        assert p3 is p2
    finally:
        clear_program_cache()
        set_program_cache_dir(prev)


# -- compile stats on run results ---------------------------------------------
def test_run_results_expose_compile_stats():
    clear_program_cache()
    dev = ZnsDevice(SPEC)
    wl = _pool(threads=3, qd=2, n=60)
    res = dev.run(wl, backend="vectorized", jitter=False)
    assert isinstance(res.compile_stats, CompileStats)
    assert res.compile_stats.misses == 1
    res2 = dev.run(wl, backend="vectorized", jitter=False, seed=5)
    assert res2.compile_stats.hits == 1
    assert dev.run(wl, backend="event", jitter=False).compile_stats is None

    fleet = DeviceFleet.homogeneous(3, SPEC)
    fres = fleet.run(wl, policy="replicate", backend="vectorized",
                     jitter=False)
    assert isinstance(fres.compile_stats, CompileStats)
    assert fres.compile_stats.n_devices == 3
    assert fres.compile_stats.n_unique in (0, 1)   # replicas dedup
    d = fres.compile_stats.to_json()
    assert set(d) >= {"hits", "misses", "disk_hits", "lowering_ms",
                      "n_devices", "n_unique"}


# -- hypothesis property: random heterogeneous fleets -------------------------
if HAVE_HYPOTHESIS:
    from hypothesis import given, settings
    import hypothesis.strategies as st

    _tier = st.sampled_from(["pool", "write", "read"])

    def _tier_wl(kind, n):
        if kind == "pool":
            return _pool(threads=3, qd=2, n=n)
        if kind == "write":
            return WorkloadSpec().writes(n=3 * n, qd=4, zone=7)
        return WorkloadSpec().reads(n=3 * n, size=4 * KiB, qd=4, nzones=64)

    @settings(max_examples=10, deadline=None)
    @given(tiers=st.lists(st.tuples(_tier, st.integers(20, 60),
                                    st.integers(1, 2)),
                          min_size=1, max_size=3),
           warm=st.booleans())
    def test_property_sharded_equals_single_chip(tiers, warm):
        clear_shard_plans()
        wls = []
        for kind, n, reps in tiers:
            wls.extend([_tier_wl(kind, n)] * reps)
        prog = _fleet_program(wls)
        comp0 = prog.issue_flat + prog.svc0_flat if warm else None
        _assert_sharded_matches(prog, comp0=comp0)
