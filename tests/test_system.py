"""End-to-end behaviour tests: train a tiny model with checkpointing and
failure/restart, verify loss decreases and decode agrees with forward."""
import dataclasses

import numpy as np
import pytest

pytestmark = pytest.mark.slow  # end-to-end suite: skipped by -m "not slow"

import jax
import jax.numpy as jnp

from repro import models as M
from repro.configs import get_smoke_config
from repro.data import DataConfig, TokenPipeline
from repro.optim import AdamWConfig
from repro.runtime import ZonedCheckpointStore
from repro.core import MiB, ZNSDeviceSpec
from repro.train import TrainState, make_train_step

KEY = jax.random.PRNGKey(7)
SMALL_SPEC = ZNSDeviceSpec(zone_size_bytes=8 * MiB, zone_cap_bytes=4 * MiB,
                           num_zones=128, max_open_zones=6,
                           max_active_zones=8)


def test_training_reduces_loss():
    cfg = get_smoke_config("tinyllama-1.1b")
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8))
    state = TrainState.create(cfg, KEY)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                         weight_decay=0.0)))
    losses = []
    for _ in range(40):
        state, metrics = step(state, jax.tree.map(jnp.asarray, next(data)))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_checkpoint_restart_resumes_bit_exact(tmp_path):
    cfg = get_smoke_config("qwen3-4b")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3,
                                                    warmup_steps=0)))
    store = ZonedCheckpointStore(str(tmp_path), n_hosts=2, spec=SMALL_SPEC)

    # run 1: 6 steps, checkpoint at 3
    data = TokenPipeline(dcfg)
    state = TrainState.create(cfg, KEY)
    for i in range(6):
        if i == 3:
            store.save(3, {"params": jax.tree.map(np.asarray, state.params),
                           "opt": jax.tree.map(np.asarray, state.opt),
                           "step": np.asarray(state.step)},
                       extra_meta={"data": data.state_dict()})
        state, _ = step(state, jax.tree.map(jnp.asarray, next(data)))
    final_a = jax.tree.leaves(state.params)[0]

    # run 2: restore at 3, replay steps 3..5
    fresh = TrainState.create(cfg, jax.random.PRNGKey(99))
    like = {"params": jax.tree.map(np.asarray, fresh.params),
            "opt": jax.tree.map(np.asarray, fresh.opt),
            "step": np.asarray(fresh.step)}
    restored, manifest = store.restore(3, like)
    data2 = TokenPipeline(dcfg)
    data2.load_state_dict(manifest["meta"]["data"])
    state2 = TrainState(step=jnp.asarray(restored["step"]),
                        params=jax.tree.map(jnp.asarray, restored["params"]),
                        opt=jax.tree.map(jnp.asarray, restored["opt"]))
    for _ in range(3):
        state2, _ = step(state2, jax.tree.map(jnp.asarray, next(data2)))
    final_b = jax.tree.leaves(state2.params)[0]
    np.testing.assert_array_equal(np.asarray(final_a), np.asarray(final_b))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen3-4b",
                                  "recurrentgemma-9b"])
def test_prefill_plus_decode_matches_forward(arch):
    """Stepwise decode logits == full-forward logits at the same positions.

    f32 compute: this asserts *algorithmic* equivalence of the two
    schedules; bf16 accumulation-order noise is covered by smoke tests.
    """
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    if cfg.window:
        cfg = dataclasses.replace(cfg, window=32)
    params = M.init_params(cfg, KEY)
    b, s = 2, 32
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full_logits, _ = M.forward(cfg, params, toks)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        prefix = 16
        logits_p, cache = M.prefill(cfg, params, toks[:, :prefix], s)
        np.testing.assert_allclose(
            np.asarray(logits_p[:, 0], np.float32),
            np.asarray(full_logits[:, prefix - 1], np.float32),
            atol=2e-2, rtol=2e-2)
    else:
        # recurrent: step from scratch and compare at each position
        cache = M.init_cache(cfg, b, s)
        for pos in range(4):
            logits_d, cache = M.decode_step(cfg, params, cache,
                                            toks[:, pos], jnp.int32(pos))
            np.testing.assert_allclose(
                np.asarray(logits_d, np.float32),
                np.asarray(full_logits[:, pos], np.float32),
                atol=3e-2, rtol=3e-2)


def test_dense_decode_steps_match_forward():
    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"),
                              dtype="float32")
    params = M.init_params(cfg, KEY)
    b, s = 1, 12
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full_logits, _ = M.forward(cfg, params, toks)
    cache = M.init_cache(cfg, b, s)
    for pos in range(s):
        logits_d, cache = M.decode_step(cfg, params, cache, toks[:, pos],
                                        jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(full_logits[:, pos], np.float32),
            atol=3e-2, rtol=3e-2)


def test_mamba_decode_matches_forward():
    cfg = dataclasses.replace(get_smoke_config("mamba2-370m"),
                              dtype="float32")
    params = M.init_params(cfg, KEY)
    b, s = 1, 8
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full_logits, _ = M.forward(cfg, params, toks)
    cache = M.init_cache(cfg, b, s)
    for pos in range(s):
        logits_d, cache = M.decode_step(cfg, params, cache, toks[:, pos],
                                        jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(full_logits[:, pos], np.float32),
            atol=3e-2, rtol=3e-2)
