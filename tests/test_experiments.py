"""Observation registry + fleet-batched experiment runner.

Acceptance: all 15 experiments (13 paper observations + the obs14/obs15
open-loop scenario extensions) execute as ONE fleet-batched sweep and
every ``check()`` passes on both simulation backends.
"""
import json
import warnings

import numpy as np
import pytest

from repro.core import KiB, WorkloadSpec
from repro.experiments import (
    Check, Experiment, ExperimentRunner, SweepPoint, all_experiments,
    get_experiment, register_experiment, render_report, unregister_experiment,
)
from repro.experiments.__main__ import main as cli_main


# -- registry ------------------------------------------------------------------
def test_registry_has_all_13_observations():
    exps = all_experiments()
    assert [e.obs for e in exps] == list(range(1, 16))
    assert len({e.name for e in exps}) == 15


def test_get_experiment_lookup_forms():
    e = get_experiment("obs04_append_vs_write")
    assert get_experiment(4) is e
    assert get_experiment("obs4") is e
    assert get_experiment("obs04") is e
    assert get_experiment("append_vs_write") is e      # unique substring
    with pytest.raises(KeyError, match="unknown experiment"):
        get_experiment("obs_nope")
    with pytest.raises(KeyError, match="no experiment"):
        get_experiment(99)


def _dummy_experiment(name="dummy_exp", obs=1):
    return Experiment(
        name=name, obs=obs, title="t", claim="c", figure="f",
        points=(SweepPoint("p", WorkloadSpec().writes(n=4, size=4 * KiB)),),
        extract=lambda ctx: {"n": float(len(ctx["p"]))},
        check=lambda m: (Check("has_requests", m["n"] == 4.0, f"n={m['n']}"),))


def test_register_experiment_collision_warns_and_unregister_roundtrip():
    exp = _dummy_experiment()
    register_experiment(exp)
    try:
        with pytest.warns(RuntimeWarning, match="already registered"):
            register_experiment(_dummy_experiment())
        # replace=True and re-registering the current object stay silent
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            current = register_experiment(_dummy_experiment(), replace=True)
            register_experiment(current)
    finally:
        unregister_experiment("dummy_exp")
    with pytest.raises(KeyError):
        get_experiment("dummy_exp")
    unregister_experiment("dummy_exp")  # idempotent


def test_experiment_validation():
    with pytest.raises(ValueError, match="obs must be"):
        _dummy_experiment(obs=0)
    bad = _dummy_experiment()
    with pytest.raises(ValueError, match="duplicate sweep-point labels"):
        Experiment(name="x", obs=1, title="t", claim="c", figure="f",
                   points=bad.points + bad.points,
                   extract=bad.extract, check=bad.check)


# -- the acceptance criterion --------------------------------------------------
@pytest.mark.parametrize("backend", ["vectorized", "event"])
def test_all_13_checks_pass_on_backend(backend):
    results = ExperimentRunner(backend=backend).run()
    assert len(results) == 15
    failures = [str(c) for r in results for c in r.checks if not c.ok]
    assert not failures, failures
    assert all(r.backend == backend for r in results)
    # one fleet-batched sweep covers every sweep point
    assert sum(r.n_requests for r in results) > 50_000


def test_runner_subset_and_custom_seed():
    res = ExperimentRunner(["obs4", 9], backend="event", seed=3).run()
    assert [r.obs for r in res] == [4, 9]
    assert all(r.passed for r in res)


def test_runner_deterministic_across_backends():
    a = ExperimentRunner(["obs13"], backend="event").run()[0]
    b = ExperimentRunner(["obs13"], backend="vectorized").run()[0]
    for k in a.metrics:
        assert a.metrics[k] == pytest.approx(b.metrics[k], rel=1e-9), k


# -- artifacts -----------------------------------------------------------------
def test_artifacts_json_and_report(tmp_path):
    runner = ExperimentRunner(["obs4", "obs13"])
    results = runner.run()
    paths = runner.write_artifacts(results, out_dir=str(tmp_path))
    data = json.loads((tmp_path / "obs04_append_vs_write.json").read_text())
    assert data["obs"] == 4 and data["passed"] is True
    assert data["metrics"]["gap_pct"] == pytest.approx(23.42, abs=0.5)
    assert data["knobs"] and data["tests"] and data["claim"]
    report = (tmp_path / "report.md").read_text()
    assert "observations.md" in report        # cross-links the docs tree
    assert "obs13_reset_inflation" in report
    assert paths["report"].endswith("report.md")


def test_report_links_docs_tree_relative(tmp_path):
    # when the artifact dir lives inside the repo, the report's docs link
    # resolves relative to it
    out = tmp_path / "repo" / "results" / "experiments"
    out.mkdir(parents=True)
    docs = tmp_path / "repo" / "docs"
    docs.mkdir()
    (docs / "observations.md").write_text("# map\n")
    results = ExperimentRunner(["obs4"]).run()
    report = render_report(results, out_dir=str(out))
    assert "../../docs/observations.md" in report


# -- CLI -----------------------------------------------------------------------
def test_cli_run_and_list(tmp_path, capsys):
    assert cli_main(["list"]) == 0
    assert "obs04_append_vs_write" in capsys.readouterr().out
    rc = cli_main(["run", "--only", "obs4,obs9", "--backend", "event",
                   "--out", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "2/2 experiments passed" in out
    assert (tmp_path / "report.md").exists()
    assert (tmp_path / "obs09_transitions.json").exists()


def test_cli_host_scenarios(tmp_path, capsys):
    assert cli_main(["host", "--list"]) == 0
    out = capsys.readouterr().out
    assert "scenario  lsm" in out and "policy    striped" in out
    rc = cli_main(["host", "--scenarios", "circular-log", "--scale", "0.5",
                   "--backend", "event", "--out", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "best-first" in out
    rows = json.loads((tmp_path / "host_policies.json").read_text())
    assert {r["policy"] for r in rows} >= {"greedy-open", "striped"}
    assert all(r["scenario"] == "circular-log" for r in rows)
    assert cli_main(["host", "--scenarios", "nope"]) == 2


def test_cli_requires_selection(capsys):
    assert cli_main(["run"]) == 2
    # an effectively-empty --only (stray comma / empty shell var) is
    # rejected too, not silently "0/0 passed"
    assert cli_main(["run", "--only", ","]) == 2


def test_cli_unknown_key_clean_error(capsys):
    assert cli_main(["run", "--only", "obs99"]) == 2
    assert "no experiment" in capsys.readouterr().err
    assert cli_main(["run", "--only", "obs_nope"]) == 2
    assert "unknown experiment" in capsys.readouterr().err


def test_cli_reports_failure_nonzero(tmp_path):
    bad = Experiment(
        name="always_fails", obs=1, title="t", claim="c", figure="f",
        points=(SweepPoint("p", WorkloadSpec().writes(n=4, size=4 * KiB)),),
        extract=lambda ctx: {"n": float(len(ctx["p"]))},
        check=lambda m: (Check("nope", False, "forced failure"),))
    register_experiment(bad)
    try:
        assert cli_main(["run", "--only", "always_fails",
                         "--out", str(tmp_path)]) == 1
        data = json.loads((tmp_path / "always_fails.json").read_text())
        assert data["passed"] is False
    finally:
        unregister_experiment("always_fails")


# -- fleet stacking details ----------------------------------------------------
def test_obs12_points_share_seed_in_batched_run():
    # quiet/loud completions compare exactly because the runner pins both
    # points to the same seed inside the heterogeneous fleet batch
    res = ExperimentRunner(["obs12"]).run()[0]
    assert res.metrics["max_read_shift_us"] == 0.0


def test_length_buckets_bound_padding_waste():
    from repro.core.fleet import length_buckets
    lens = [40, 45, 30_000, 90, 24_000, 120]
    buckets = length_buckets(lens)
    assert sorted(i for b in buckets for i in b) == list(range(len(lens)))
    for b in buckets:
        vals = [lens[i] for i in b]
        assert max(vals) <= 4.0 * max(min(vals), 1)
    assert length_buckets([]) == []
    assert length_buckets([0, 0, 3]) == [[0, 1, 2]]   # zeros clamp to base 1
    assert length_buckets([0, 0, 5]) == [[0, 1], [2]]
