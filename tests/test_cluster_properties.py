"""Cluster-tier invariants under randomized inputs (hypothesis, via the
suite's importorskip convention — deterministic sweeps of the same
properties live in ``tests/test_cluster.py`` so coverage survives
without hypothesis installed).

Three properties from the issue spec:

1. every object byte maps to exactly one data shard (codec partition);
2. EC degraded reconstruction touches exactly ``m`` servers beyond the
   normal-mode read set;
3. the fleet-level ChainProgram's completions match the greedy
   event-engine oracle to float tolerance on jitter-free configs.
"""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.cluster import (
    Cluster, ClusterSpec, ClusterWorkload, build_graph, erasure, replication,
    simulate_graph, touched_servers, OP_GET,
)

TOL_US = 1e-6


def schemes():
    return st.one_of(
        st.tuples(st.integers(1, 6), st.integers(0, 3)).map(
            lambda km: erasure(*km)),
        st.tuples(st.integers(1, 4), st.integers(1, 3)).map(
            lambda kc: replication(kc[0], copies=kc[1])),
    )


@given(scheme=schemes(), nbytes=st.integers(1, 1 << 22),
       offset=st.integers(0, (1 << 22) - 1))
@settings(max_examples=200, deadline=None)
def test_every_byte_in_exactly_one_data_shard(scheme, nbytes, offset):
    ranges = scheme.shard_ranges(nbytes)
    pos = 0
    for lo, hi in ranges:                    # contiguous partition
        assert lo == pos and hi >= lo
        pos = hi
    assert pos == nbytes
    offset %= nbytes
    holders = [j for j, (lo, hi) in enumerate(ranges) if lo <= offset < hi]
    assert holders == [scheme.shard_of_byte(nbytes, offset)]


@given(k=st.integers(2, 4), m=st.integers(1, 2),
       policy=st.sampled_from(["round-robin", "strided", "hashed"]),
       seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_ec_degraded_reconstruction_touches_exactly_m_extra(k, m, policy,
                                                            seed):
    scheme = erasure(k, m)
    spec = ClusterSpec(n_gateways=2, n_servers=scheme.n_shards + 2,
                       scheme=scheme, placement=policy)
    wl = ClusterWorkload(n_users=2, ops_per_user=4, get_fraction=0.5,
                         object_bytes=1 << 20, seed=seed)
    ops = wl.build(spec.n_gateways)
    normal = build_graph(spec, ops, qd=1, seed=seed)
    for down in range(spec.n_servers):
        degraded = build_graph(spec, ops, qd=1, down=down, seed=seed)
        for op in ops:
            if op.kind != OP_GET:
                continue
            before = touched_servers(normal, op.seq)
            after = touched_servers(degraded, op.seq)
            if down not in before:
                continue
            assert down not in after
            assert len(after - before) == m


@given(scheme=st.sampled_from([erasure(2, 1), erasure(3, 0),
                               replication(2, 2), replication(1, 3)]),
       policy=st.sampled_from(["round-robin", "grouped", "hashed"]),
       durability=st.sampled_from(["writeback", "write-through"]),
       qd=st.integers(1, 2), seed=st.integers(0, 20),
       degrade=st.booleans())
@settings(max_examples=15, deadline=None)
def test_program_matches_oracle_jitter_free(scheme, policy, durability, qd,
                                            seed, degrade):
    spec = ClusterSpec(n_gateways=2, n_servers=8, scheme=scheme,
                       placement=policy, durability=durability)
    wl = ClusterWorkload(n_users=3, ops_per_user=3, get_fraction=0.5,
                         object_bytes=1 << 20, qd=qd, seed=seed)
    down = 0 if degrade and scheme.m >= 1 else None
    res = Cluster(spec).run(wl, down=down)
    assert res.converged and res.compiled.program.order_stable
    oracle = simulate_graph(res.compiled.graph)
    assert float(np.max(np.abs(res.comp - oracle))) < TOL_US
