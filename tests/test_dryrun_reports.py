"""Deliverable gate: the 40-cell dry-run sweep must be complete and green.

Reads reports/dryrun (committed sweep output).  Skips if the sweep
hasn't been run in this checkout.
"""
import glob
import json
import os

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN = os.path.join(REPO, "reports", "dryrun")

ARCHS = ("tinyllama-1.1b", "qwen3-4b", "qwen3-8b", "llama3-405b",
         "arctic-480b", "qwen2-moe-a2.7b", "mamba2-370m", "internvl2-26b",
         "musicgen-large", "recurrentgemma-9b")
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
SUBQUADRATIC = ("mamba2-370m", "recurrentgemma-9b")

pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(DRYRUN, "*.json")),
    reason="dry-run sweep not present (run scripts/run_dryrun_sweep.sh)")


@pytest.mark.parametrize("mesh", ("single", "multi"))
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("arch", ARCHS)
def test_cell_report(arch, shape, mesh):
    path = os.path.join(DRYRUN, f"{arch}_{shape}_{mesh}.json")
    assert os.path.exists(path), f"missing sweep cell {path}"
    with open(path) as f:
        r = json.load(f)
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        assert r["status"] == "skipped"
        assert "sub-quadratic" in r["reason"]
        return
    assert r["status"] == "ok", r.get("error")
    mem = r["full"]["memory"]
    assert mem["temp_bytes"] >= 0 and mem["argument_bytes"] > 0
    assert r["full"]["flops"] > 0
    # multi-pod runs must actually use 512 chips
    chips = 1
    for v in r["mesh_shape"].values():
        chips *= v
    assert chips == (512 if mesh == "multi" else 256)


def test_roofline_terms_positive():
    import sys
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.launch.roofline import load_cells, roofline_row
    cells = load_cells([DRYRUN, os.path.join(REPO, "reports",
                                             "dryrun_fitfix")])
    n = 0
    for key, r in cells.items():
        if key[2] != "single" or r.get("status") != "ok":
            continue
        row = roofline_row(r)
        assert row["t_compute_s"] > 0
        assert row["t_memory_s"] > 0
        assert row["dominant"] in ("compute", "memory", "collective")
        assert 0 < row["useful_flop_ratio"] < 20
        n += 1
    assert n >= 30
