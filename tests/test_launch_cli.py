"""CLI drivers end-to-end (subprocess): train, serve, roofline."""
import glob
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # end-to-end suite: skipped by -m "not slow"

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=560, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.update(env_extra or {})
    out = subprocess.run([sys.executable] + args, capture_output=True,
                         text=True, env=env, timeout=timeout, cwd=REPO)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    return out.stdout


def test_train_driver_runs_and_checkpoints(tmp_path):
    out = _run(["-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
                "--smoke", "--steps", "30", "--batch", "4",
                "--seq-len", "64", "--ckpt-dir", str(tmp_path),
                "--ckpt-every", "10", "--log-every", "10"])
    assert "[train] done" in out
    assert "ckpt@10" in out
    # restart resumes from the latest checkpoint
    out2 = _run(["-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
                 "--smoke", "--steps", "35", "--batch", "4",
                 "--seq-len", "64", "--ckpt-dir", str(tmp_path),
                 "--ckpt-every", "100", "--log-every", "5"])
    assert "restored step 30" in out2


def test_serve_driver_completes_requests():
    out = _run(["-m", "repro.launch.serve", "--arch", "tinyllama-1.1b",
                "--smoke", "--requests", "6", "--batch", "2",
                "--max-new", "8", "--max-seq", "64"])
    assert "[serve] 6/6 requests" in out


@pytest.mark.skipif(
    not glob.glob(os.path.join(REPO, "reports", "dryrun", "*.json")),
    reason="dry-run sweep not present (run scripts/run_dryrun_sweep.sh)")
def test_roofline_aggregator_emits_rows():
    out = _run(["-m", "repro.launch.roofline", "--in", "reports/dryrun",
                "reports/dryrun_fitfix"])
    lines = [l for l in out.splitlines() if l and not l.startswith("arch")]
    assert len(lines) >= 30            # 32 runnable single-pod cells
    assert any("llama3-405b" in l for l in lines)
