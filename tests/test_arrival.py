"""Open-loop arrival processes: lowering, validation regressions, the
obs14/obs15 scenario experiments, and the event-oracle differential.

Covers the PR's two lowering bugfixes as regressions (a zero
``rate_bytes_per_s`` used to escape as a bare ``ZeroDivisionError``; a
paced zero-size stream silently degraded to closed-loop) plus the
tentpole contract: every arrival process lowers to explicit issue-time
vectors that both backends consume, so vectorized completions match the
event oracle to 1e-9 on open-loop traffic.
"""
import numpy as np
import pytest

from repro.core import (
    DeterministicRate, KiB, MarkovModulated, OpType, PoissonArrivals,
    TraceReplay, WorkloadSpec, ZnsDevice, spread_into_windows,
)
from repro.core.workload import StreamSpec
from strategies import HAVE_HYPOTHESIS


# ---------------------------------------------------------------------------
# Arrival-process primitives
# ---------------------------------------------------------------------------
def test_deterministic_rate_three_spellings_agree():
    size = 8 * KiB
    by_every = DeterministicRate(every_us=20.0)
    by_rate = DeterministicRate(rate_per_s=50_000.0)
    by_bytes = DeterministicRate(rate_bytes_per_s=size * 50_000.0)
    t = by_every.issue_times(10, start_us=5.0)
    assert np.allclose(t, by_rate.issue_times(10, start_us=5.0))
    assert np.allclose(t, by_bytes.issue_times(10, start_us=5.0, size=size))
    assert t[0] == 5.0 and np.allclose(np.diff(t), 20.0)


def test_deterministic_rate_validation():
    with pytest.raises(ValueError, match="exactly one of"):
        DeterministicRate()
    with pytest.raises(ValueError, match="exactly one of"):
        DeterministicRate(every_us=1.0, rate_per_s=1.0)
    with pytest.raises(ValueError, match="must be finite and > 0"):
        DeterministicRate(every_us=0.0)
    with pytest.raises(ValueError, match="must be finite and > 0"):
        DeterministicRate(rate_bytes_per_s=-1.0)
    # byte-rate pacing without a size cannot silently mean "pace 0"
    with pytest.raises(ValueError, match="size > 0"):
        DeterministicRate(rate_bytes_per_s=1e6).issue_times(4, size=0)


@pytest.mark.parametrize("proc", [
    PoissonArrivals(rate_per_s=50_000.0, seed=3),
    MarkovModulated(rate_on_per_s=1e5, mean_on_us=400.0, mean_off_us=900.0,
                    seed=3),
])
def test_random_processes_seeded_and_monotone(proc):
    a = proc.issue_times(200)
    b = proc.issue_times(200)
    assert np.array_equal(a, b)                    # same seed, same draw
    assert (np.diff(a) >= 0.0).all() and len(a) == 200
    import dataclasses
    other = dataclasses.replace(proc, seed=proc.seed + 1)
    assert not np.array_equal(a, other.issue_times(200))


def test_mmpp_off_state_creates_gaps():
    proc = MarkovModulated(rate_on_per_s=1e6, rate_off_per_s=0.0,
                           mean_on_us=200.0, mean_off_us=5_000.0, seed=0)
    gaps = np.diff(proc.issue_times(400))
    # bursts at ~1 us spacing, punctuated by ~ms-scale off dwells
    assert gaps.max() > 50.0 * np.median(gaps)


def test_trace_replay_inline_file_and_underflow(tmp_path):
    inline = TraceReplay(times_us=(30.0, 10.0, 20.0))
    assert np.array_equal(inline.issue_times(3), [10.0, 20.0, 30.0])
    p = tmp_path / "arrivals.txt"
    p.write_text("# one burst\n10 20\n\n30.5\n")
    assert np.array_equal(TraceReplay(path=str(p)).issue_times(3),
                          [10.0, 20.0, 30.5])
    with pytest.raises(ValueError, match="holds 3 issue times"):
        inline.issue_times(4)
    with pytest.raises(ValueError, match="exactly one of"):
        TraceReplay()
    with pytest.raises(ValueError, match="exactly one of"):
        TraceReplay(times_us=(1.0,), path="x")


def test_spread_into_windows_apportionment():
    t = spread_into_windows(5, [(0.0, 100.0), (200.0, 260.0)])
    assert len(t) == 5 and (np.diff(t) > 0).all()
    # shares proportional to window length (100:60 -> 3:2), half-step inset
    assert (t[:3] > 0).all() and (t[:3] < 100).all()
    assert (t[3:] > 200).all() and (t[3:] < 260).all()
    assert len(spread_into_windows(0, [(0.0, 1.0)])) == 0
    with pytest.raises(ValueError, match="start < end"):
        spread_into_windows(3, [(5.0, 5.0)])
    with pytest.raises(ValueError, match="start < end"):
        spread_into_windows(3, [])


# ---------------------------------------------------------------------------
# Stream lowering: validation regressions + open-loop semantics
# ---------------------------------------------------------------------------
def test_zero_byte_rate_rejected_not_zero_division():
    # regression: used to escape _lower_io as a bare ZeroDivisionError
    with pytest.raises(ValueError, match="rate_bytes_per_s must be > 0"):
        WorkloadSpec().writes(n=4, size=4 * KiB, rate_bytes_per_s=0.0)


def test_paced_zero_size_stream_rejected_not_silent():
    # regression: size=0 made the pace 0, silently closed-loop
    with pytest.raises(ValueError, match="silently degrade"):
        StreamSpec(op=OpType.READ, n=4, size=0, rate_bytes_per_s=1e6)


def test_mgmt_occupancies_n_conflict_rejected():
    # regression: _lower_mgmt silently ignored n when occupancies was set
    with pytest.raises(ValueError, match="n=7 conflicts"):
        WorkloadSpec().stream(OpType.RESET, n=7,
                              occupancies=(0.2, 0.8), n_per_level=2)
    # reset_sweep keeps n mirrored on n_per_level, so it stays valid
    wl = WorkloadSpec().reset_sweep((0.2, 0.8), n_per_level=2)
    assert len(wl.build()) == 4


def test_arrival_conflicts_with_legacy_knobs():
    arr = DeterministicRate(every_us=5.0)
    with pytest.raises(ValueError, match="conflicts with the legacy"):
        WorkloadSpec().reads(n=4, every_us=5.0, arrival=arr)
    with pytest.raises(ValueError, match="conflicts with the legacy"):
        WorkloadSpec().reads(n=4, rate_bytes_per_s=1e6, arrival=arr)
    with pytest.raises(ValueError, match="qd must be >= 0"):
        WorkloadSpec().reads(n=4, qd=-1)


def test_legacy_knobs_lower_through_deterministic_rate():
    legacy = WorkloadSpec().writes(n=16, size=4 * KiB, qd=2,
                                   every_us=30.0).build()
    arr = WorkloadSpec().writes(
        n=16, size=4 * KiB, qd=2,
        arrival=DeterministicRate(every_us=30.0)).build()
    assert np.array_equal(legacy.issue, arr.issue)
    legacy = WorkloadSpec().writes(n=16, size=4 * KiB,
                                   rate_bytes_per_s=1e8).build()
    arr = WorkloadSpec().writes(
        n=16, size=4 * KiB,
        arrival=DeterministicRate(rate_bytes_per_s=1e8)).build()
    assert np.array_equal(legacy.issue, arr.issue)
    # every_us=0.0 is the legacy "no pacing" spelling, still accepted
    t = WorkloadSpec().writes(n=4, size=4 * KiB, every_us=0.0).build()
    assert np.array_equal(t.issue, np.zeros(4))


def test_qd0_lowers_to_unbindable_gate():
    arr = PoissonArrivals(rate_per_s=100_000.0, seed=2)
    open_wl = WorkloadSpec().reads(n=60, size=4 * KiB, qd=0, arrival=arr)
    explicit = WorkloadSpec().reads(n=60, size=4 * KiB, qd=60, arrival=arr)
    gated = WorkloadSpec().reads(n=60, size=4 * KiB, qd=1, arrival=arr)
    dev = ZnsDevice()
    a = dev.run(open_wl, backend="event", jitter=False).sim.complete
    b = dev.run(explicit, backend="event", jitter=False).sim.complete
    c = dev.run(gated, backend="event", jitter=False).sim.complete
    assert np.array_equal(a, b)          # qd=0 == "qd >= n"
    assert c.max() > a.max()             # a binding gate actually delays


def test_mgmt_stream_takes_arrival_clock():
    times = (100.0, 2_000.0, 2_500.0, 9_000.0)
    tr = WorkloadSpec().resets(
        n=4, occupancy=1.0, nzones=4, qd=0,
        arrival=TraceReplay(times_us=times)).build()
    assert np.array_equal(tr.issue, times)


def test_reclaim_windows_schedule_into_troughs():
    from repro.host import ReclaimScheduler
    dev = ZnsDevice()
    sched = ReclaimScheduler(dev, io_ctx=OpType.READ)
    sched.schedule(range(6))
    windows = ((1_000.0, 4_000.0), (8_000.0, 11_000.0))
    wl = sched.reclaim_workload(windows=windows)
    tr = wl.build()
    resets = tr.issue[tr.op == int(OpType.RESET)]
    assert len(resets) == 6
    assert all(any(lo <= t <= hi for lo, hi in windows) for t in resets)
    assert sched.backlog == list(range(6))   # compile does not drain


def test_qlat_metrics_register_submission_latency():
    wl = WorkloadSpec().reads(
        n=200, size=4 * KiB, qd=0,
        arrival=MarkovModulated(rate_on_per_s=5e5, mean_on_us=300.0,
                                mean_off_us=1_000.0, seed=1))
    res = ZnsDevice().run(wl, backend="event", jitter=False)
    m = res.summary(["lat_p99_us", "qlat_p50_us", "qlat_p99_us",
                     "qlat_p999_us"])
    # complete - issue >= complete - start, elementwise -> every quantile
    assert m["qlat_p99_us"] >= m["lat_p99_us"]
    assert m["qlat_p999_us"] >= m["qlat_p99_us"] >= m["qlat_p50_us"] > 0.0


# ---------------------------------------------------------------------------
# The registry scenarios (obs14 / obs15)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["vectorized", "event"])
def test_obs14_noisy_neighbor_registry_checks(backend):
    from repro.experiments import ExperimentRunner
    res = ExperimentRunner(["obs14"], backend=backend).run()[0]
    failures = [str(c) for c in res.checks if not c.ok]
    assert not failures, failures
    m = res.metrics
    assert m["max_read_shift_us"] <= 1e-6          # ZN540: Obs#12 at scale
    assert m["nv_tail_ratio_40"] > 2.0             # data-path erase bites
    assert m["oracle_max_rel_diff"] <= 1e-9        # open-loop exactness
    assert m["read_ctx_inflation_pct"] == pytest.approx(56.11, rel=0.05)


@pytest.mark.parametrize("backend", ["vectorized", "event"])
def test_obs15_diurnal_reclaim_registry_checks(backend):
    from repro.experiments import ExperimentRunner
    res = ExperimentRunner(["obs15"], backend=backend).run()[0]
    failures = [str(c) for c in res.checks if not c.ok]
    assert not failures, failures
    m = res.metrics
    assert m["trough_read_shift_us"] <= 1e-6       # troughs hide reclaim
    assert m["p999_uniform_us"] > 5.0 * m["p999_trough_us"]
    assert m["resets_uniform"] == m["resets_trough"]   # same work, worse tail
    assert m["zn540_read_shift_us"] <= 1e-6


# ---------------------------------------------------------------------------
# Cluster capacity: open-loop offered load
# ---------------------------------------------------------------------------
def test_cluster_workload_arrival_stamps_issue_times():
    from repro.cluster import ClusterWorkload
    wl = ClusterWorkload(n_users=4, ops_per_user=6, seed=2,
                         arrival=PoissonArrivals(rate_per_s=5_000.0, seed=1))
    ops = wl.build(n_gateways=2)
    times = np.asarray([op.issue for op in ops])
    assert (np.diff(times) >= 0).all() and times[0] > 0.0
    # the op mix survives the open-loop lowering (not all PUTs)
    kinds = {op.kind for op in ops}
    assert len(kinds) >= 2
    closed = ClusterWorkload(n_users=4, ops_per_user=6, seed=2).build(2)
    assert all(op.issue == 0.0 for op in closed)


def test_plan_capacity_rate_ladder_ranks_by_rate_at_slo():
    from repro.cluster import (ClusterConfig, ClusterSpec, ClusterWorkload,
                               erasure, plan_capacity)
    spec = ClusterSpec(n_gateways=1, n_servers=4, scheme=erasure(2, 1))
    rep = plan_capacity(
        [ClusterConfig(erasure(2, 1), "round-robin")], (),
        rate_ladder=[500.0, 4_000.0, 32_000.0],
        workload=ClusterWorkload(n_users=3, ops_per_user=5),
        base_spec=spec, slo_us=4_000.0, degraded=False)
    assert rep.converged
    (curve,) = rep.curves
    rates = [p.offered_rate for p in curve.points]
    p99s = [p.lat.p99_us for p in curve.points]
    assert rates == [500.0, 4_000.0, 32_000.0]
    assert p99s == sorted(p99s)                    # offered load drives p99
    assert all(p.users == 3 for p in curve.points)
    assert curve.rate_at_slo is not None
    assert curve.load_at_slo == curve.rate_at_slo
    assert 500.0 <= curve.rate_at_slo <= 32_000.0
    assert "rate_at_slo" in curve.to_json()
    assert curve.points[0].to_json()["offered_rate"] == 500.0
    # closed-loop sweeps keep the legacy shape: no offered_rate anywhere
    rep2 = plan_capacity(
        [ClusterConfig(erasure(2, 1), "round-robin")], [2, 3],
        workload=ClusterWorkload(ops_per_user=4), base_spec=spec,
        degraded=False)
    assert all(p.offered_rate is None
               for c in rep2.curves for p in c.points)
    assert rep2.curves[0].rate_at_slo is None


# ---------------------------------------------------------------------------
# Differential: open-loop traces vs the event oracle
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    from hypothesis import given, settings
    from strategies import open_loop_workload_specs

    @given(wl=open_loop_workload_specs())
    @settings(max_examples=15, deadline=None)
    def test_open_loop_vectorized_matches_event_oracle(wl):
        dev = ZnsDevice()
        ref = dev.run(wl, backend="event", jitter=False)
        got = dev.run(wl, backend="vectorized", jitter=False)
        scale = np.maximum(np.abs(ref.sim.complete), 1.0)
        np.testing.assert_allclose(got.sim.complete, ref.sim.complete,
                                   rtol=0, atol=1e-9 * scale.max())
        # submission-to-completion latency (what the SLO scenarios gate
        # on) must agree too, not just the completion clock
        np.testing.assert_allclose(
            got.sim.latency_from(got.trace.issue),
            ref.sim.latency_from(ref.trace.issue),
            rtol=0, atol=1e-9 * scale.max())
