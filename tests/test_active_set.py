"""Active-set sweeps + windowed pipeline solver (PR 10).

The acceptance bar is the ISSUE gate: the active-set Gauss-Seidel
driver and the issue-time-window pipeline must equal the full solve to
1e-12 across pool and open-loop workloads, both block layouts, the
host and mesh shard executors, with and without a ``comp0`` warm
start.  Equality is checked against an *independent* Bellman (Jacobi)
reference that never touches the production sweep loop, plus the
:func:`repro.core.chain_program.verify_fixpoint` tightness oracle.

Rides along: regression tests for the PR 10 satellites — the
shard-plan digest cache key, ``unjustified_slots``, and warm-started
capacity ladders (bit-identical curves + warm-hit accounting).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    KiB, WorkloadSpec, ZnsDevice, ZNSDeviceSpec, clear_shard_plans,
    compile_program, force_layout, last_solve_stats, solve_program,
    solve_program_sharded, solve_program_windowed, window_program,
)
from repro.core import chain_program as cp
from repro.core import shard as shard_mod
from strategies import HAVE_HYPOTHESIS

SPEC = ZNSDeviceSpec()


def _compile(wl: WorkloadSpec, *, seed: int = 0) -> tuple:
    dev = ZnsDevice(SPEC)
    trace = wl.build()
    prog = compile_program(trace, dev.spec, dev.lat, cache=False, seed=seed)
    return prog, prog.svc0_flat


def _jacobi_reference(program, svc, *, max_iters: int = 100_000):
    """Independent fixpoint: iterate the Bellman target to convergence.

    Uses only :func:`cp._fixpoint_target` (a one-shot vectorized
    justification evaluation), never the production sweep loop — Jacobi
    from the same ``issue + svc`` lower bound converges to the same
    least fixpoint the Gauss-Seidel driver must find.
    """
    comp = program.issue_flat + svc
    for _ in range(max_iters):
        nxt = np.maximum(comp, cp._fixpoint_target(program, svc, comp))
        if np.array_equal(nxt, comp):
            return comp
        comp = nxt
    raise AssertionError("Jacobi reference did not converge")


def _assert_close(got, ref):
    np.testing.assert_allclose(got, ref, rtol=1e-12, atol=1e-9)


def _check_all_drivers(prog, svc, *, warm: bool):
    ref = _jacobi_reference(prog, svc)
    comp0 = None
    if warm:
        # a valid (partial) lower bound: the solved completions of the
        # first half of the events, -inf elsewhere
        comp0 = np.full(prog.n_flat, -np.inf)
        comp0[: prog.n_flat // 2] = ref[: prog.n_flat // 2]
    for layout in ("rows", "cols"):
        p = force_layout(prog, layout)
        got, used, conv = solve_program(p, svc, sweeps=512,
                                        fixpoint="loop", comp0=comp0)
        assert conv
        _assert_close(got, ref)
        assert cp.verify_fixpoint(p, svc, got)
        st = last_solve_stats()
        assert st.driver == "loop" and st.sweeps == used
        assert len(st.active_blocks) == used == len(st.residuals)
        # the final sweep is a verification pass: nothing moved
        assert st.residuals[-1] == 0.0
        # windowed pipeline, a handful of window counts
        for k in (2, 3, 7):
            gw, _, cw = solve_program_windowed(p, svc, sweeps=512,
                                               n_windows=k, comp0=comp0)
            assert cw
            _assert_close(gw, ref)
    return ref


# -- hypothesis sweep: pool + open-loop workloads ----------------------------
if HAVE_HYPOTHESIS:
    import hypothesis.strategies as st_h
    from hypothesis import given, settings

    from strategies import open_loop_workload_specs, pool_workload_specs

    @given(pool_workload_specs(), st_h.booleans())
    @settings(max_examples=15, deadline=None)
    def test_active_set_and_windowed_match_reference_pool(wl, warm):
        prog, svc = _compile(wl)
        _check_all_drivers(prog, svc, warm=warm)

    @given(open_loop_workload_specs(), st_h.booleans())
    @settings(max_examples=15, deadline=None)
    def test_active_set_and_windowed_match_reference_open_loop(wl, warm):
        prog, svc = _compile(wl)
        _check_all_drivers(prog, svc, warm=warm)


# -- deterministic acceptance cases (run even without hypothesis) ------------
def _pool_wl(threads=4, qd=2, n=60):
    wl = WorkloadSpec()
    for t in range(threads):
        wl = wl.appends(n=n, size=8 * KiB, qd=qd, zone=t * 4, nzones=4)
    return wl


def test_active_set_matches_reference_deterministic():
    prog, svc = _compile(_pool_wl())
    _check_all_drivers(prog, svc, warm=False)
    _check_all_drivers(prog, svc, warm=True)


def test_active_set_skips_converged_blocks():
    prog, svc = _compile(_pool_wl(threads=6, n=80))
    _, used, conv = solve_program(prog, svc, sweeps=512, fixpoint="loop")
    st = last_solve_stats()
    assert conv and used >= 2
    # sweep 1 touches every block; converged blocks drop out of later
    # sweeps (a dirty block whose edge check passes stays counted but
    # costs O(L), not a scan), so the set shrinks by the final sweep
    assert st.active_blocks[0] == st.n_blocks
    assert st.active_blocks[-1] < st.n_blocks
    assert st.residuals[-1] == 0.0


def test_windowed_solve_matches_sharded_host_executor():
    from repro.core import DeviceFleet, compile_fleet_program
    wls = [_pool_wl(threads=3, n=40),
           WorkloadSpec().writes(n=150, qd=4, zone=7),
           WorkloadSpec().reads(n=200, size=4 * KiB, qd=4, nzones=64)]
    traces = [w.build() for w in wls]
    devs = [ZnsDevice(SPEC) for _ in traces]
    prog = compile_fleet_program(traces, [d.spec for d in devs],
                                 [d.lat for d in devs], cache=False)
    svc = prog.svc0_flat
    ref = _jacobi_reference(prog, svc)
    hosted, _, ch = solve_program_sharded(prog, svc, sweeps=512,
                                          executor="host")
    assert ch
    _assert_close(hosted, ref)
    for k in (2, 5):
        gw, _, cw = solve_program_windowed(prog, svc, sweeps=512,
                                           n_windows=k)
        assert cw
        _assert_close(gw, ref)


MESH_WINDOW_SCRIPT = textwrap.dedent("""
    import os
    import numpy as np
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=2")
    import jax
    assert len(jax.local_devices()) == 2, jax.local_devices()
    from repro.core import (KiB, WorkloadSpec, ZnsDevice, ZNSDeviceSpec,
                            compile_fleet_program, solve_program,
                            solve_program_sharded, solve_program_windowed)
    wl = WorkloadSpec()
    for t in range(3):
        wl = wl.appends(n=40, size=8 * KiB, qd=2, zone=t * 4, nzones=4)
    wls = [wl, WorkloadSpec().writes(n=120, qd=4, zone=7)]
    traces = [w.build() for w in wls]
    devs = [ZnsDevice(ZNSDeviceSpec()) for _ in traces]
    prog = compile_fleet_program(traces, [d.spec for d in devs],
                                 [d.lat for d in devs], cache=False)
    ref, _, cv = solve_program(prog, prog.svc0_flat, sweeps=512,
                               fixpoint="loop")
    assert cv
    meshed, _, cm = solve_program_sharded(prog, prog.svc0_flat, sweeps=512,
                                          executor="mesh")
    assert cm
    rel = np.max(np.abs(meshed - ref) / np.maximum(np.abs(ref), 1.0))
    assert rel <= 1e-12, rel
    gw, _, cw = solve_program_windowed(prog, prog.svc0_flat, sweeps=512,
                                       n_windows=3)
    assert cw
    relw = np.max(np.abs(gw - ref) / np.maximum(np.abs(ref), 1.0))
    assert relw <= 1e-12, relw
    print("MESH_WINDOW_OK", rel, relw)
""")


def test_mesh_executor_and_windowed_agree_on_virtual_devices():
    pytest.importorskip("jax")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), os.pardir, "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run([sys.executable, "-c", MESH_WINDOW_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MESH_WINDOW_OK" in proc.stdout


def test_window_partition_is_exact_and_bounded():
    prog, svc = _compile(_pool_wl(threads=5, n=100))
    wp = window_program(prog, n_windows=4)
    # every event lands in exactly one window
    allp = np.concatenate([w.perm for w in wp.windows])
    assert len(allp) == prog.n_flat
    assert len(np.unique(allp)) == prog.n_flat
    # cross-window chain edges only point forward (pipeline order)
    for j, w in enumerate(wp.windows):
        assert (w.bnd_pred < prog.n_flat).all()
        for pred in w.bnd_pred:
            upstream = next(i for i, ww in enumerate(wp.windows)
                            if pred in set(ww.perm.tolist()))
            assert upstream < j


# -- satellite: unjustified_slots / verify_fixpoint oracle -------------------
def test_unjustified_slots_flags_overshoot_only():
    prog, svc = _compile(_pool_wl(threads=3, n=40))
    comp, _, conv = solve_program(prog, svc, sweeps=512, fixpoint="loop")
    assert conv
    assert cp.verify_fixpoint(prog, svc, comp)
    assert len(cp.unjustified_slots(prog, svc, comp)) == 0
    # inflate one slot: it (and only it) becomes unjustified
    bad = comp.copy()
    k = prog.n_flat // 2
    bad[k] += 1e3
    slots = cp.unjustified_slots(prog, svc, bad)
    assert k in slots
    assert not cp.verify_fixpoint(prog, svc, bad)
    # an under-approximation is justified everywhere (it is a lower
    # bound, not an overshoot) but is not a fixpoint
    lower = prog.issue_flat + svc
    if not np.allclose(lower, comp):
        assert not cp.verify_fixpoint(prog, svc, lower)


# -- satellite: shard-plan LRU digest fallback key ---------------------------
def test_shard_plan_cache_hits_on_equal_content_distinct_objects():
    clear_shard_plans()
    wl = _pool_wl(threads=3, n=40)
    prog_a, _ = _compile(wl)
    prog_b, _ = _compile(wl)
    assert prog_a is not prog_b
    assert shard_mod._program_digest(prog_a) == \
        shard_mod._program_digest(prog_b)
    plan_a = shard_mod._plan(prog_a, 2)
    plan_b = shard_mod._plan(prog_b, 2)
    # the digest fallback key resolves the same plan for an equal-content
    # program that misses the object-identity fast path (a rebuilt
    # capacity-ladder rung must not replan)
    assert plan_b is plan_a
    # identity fast path still hits for the same object
    assert shard_mod._plan(prog_a, 2) is plan_a
    # and the executors route through the cached plan
    ref, _, _ = solve_program_sharded(prog_a, prog_a.svc0_flat, sweeps=64,
                                      executor="host")
    got, _, _ = solve_program_sharded(prog_b, prog_b.svc0_flat, sweeps=64,
                                      executor="host")
    np.testing.assert_array_equal(got, ref)
    clear_shard_plans()


# -- satellite: warm-started capacity ladders --------------------------------
@pytest.mark.slow
def test_warm_ladder_is_bit_identical_and_hits():
    from repro.cluster import (ClusterConfig, ClusterSpec, ClusterWorkload,
                               erasure, plan_capacity)
    configs = [ClusterConfig(scheme=erasure(2, 1), placement="round-robin")]
    spec = ClusterSpec(n_gateways=1, n_servers=4, scheme=erasure(2, 1))
    wl = ClusterWorkload(n_users=6, ops_per_user=4,
                         object_bytes=1 << 20, get_fraction=0.5)
    kw = dict(base_spec=spec, workload=wl, degraded=False,
              rate_ladder=[5000.0, 10000.0, 20000.0], sweeps=512)
    cold = plan_capacity(configs, [6], warm_ladder=False, **kw)
    warm = plan_capacity(configs, [6], warm_ladder=True, **kw)
    assert warm.warm_attempts >= 1
    assert warm.warm_hits == warm.warm_attempts        # all seeds verified
    # identical curves: the warm start is an optimization, not a model
    for cc, cw in zip(cold.curves, warm.curves):
        assert cc.config.name == cw.config.name
        assert len(cc.points) == len(cw.points)
        for pc, pw in zip(cc.points, cw.points):
            assert pc.lat.p99_us == pw.lat.p99_us
            assert pc.slo_violation_rate == pw.slo_violation_rate
        assert cc.load_at_slo == cw.load_at_slo
