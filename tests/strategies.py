"""Shared test fixtures + hypothesis strategies for the ZNS model suite.

Plain helpers (spec variants, mixed workloads, fleet members) are
importable without hypothesis; the strategy factories are defined only
when hypothesis is present (``HAVE_HYPOTHESIS`` guards them, matching
the suite's importorskip convention).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    DeterministicRate, KiB, MarkovModulated, MiB, OpType, PoissonArrivals,
    Trace, TraceReplay, WorkloadSpec, ZNSDeviceSpec,
)
from repro.core.emulator_models import EMULATOR_PROFILES

try:
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

# ---------------------------------------------------------------------------
# Plain (hypothesis-free) helpers
# ---------------------------------------------------------------------------
#: Heterogeneous device geometries exercised by the fleet suites.
SPEC_VARIANTS = (
    ZNSDeviceSpec(),
    ZNSDeviceSpec(append_parallelism=4),
    ZNSDeviceSpec(num_zones=512, max_open_zones=12),
)

#: §IV latency profiles, in fidelity order.
PROFILE_NAMES = ("ours", "nvmevirt", "femu")

#: Small geometry for state-machine / allocator tests (fast fills).
SMALL_SPEC = ZNSDeviceSpec(zone_size_bytes=1 << 20, zone_cap_bytes=1 << 19,
                           num_zones=32, max_open_zones=4,
                           max_active_zones=6)


def fleet_members(n: int):
    """n heterogeneous (spec, params) members cycling the variants."""
    return [(SPEC_VARIANTS[i % len(SPEC_VARIANTS)],
             EMULATOR_PROFILES[PROFILE_NAMES[i % len(PROFILE_NAMES)]])
            for i in range(n)]


def mixed_workload(scale: int, *, with_mgmt: bool = True) -> WorkloadSpec:
    """The suite's canonical mixed workload: writes + reads + appends,
    optionally with the full management-op complement."""
    wl = (WorkloadSpec()
          .writes(n=6 * scale, qd=4, zone=0)
          .reads(n=6 * scale, qd=8, zone=100, nzones=50)
          .appends(n=4 * scale, qd=2, zone=200))
    if with_mgmt:
        wl = (wl.resets(n=max(scale // 2, 1), occupancy=1.0, nzones=64,
                        io_ctx=OpType.READ)
              .finishes(n=max(scale // 10, 1), occupancy=0.3)
              .opens(n=2).closes(n=2))
    return wl


def random_io_trace(n: int, qd: int, seed: int, *,
                    n_zones: int = 10, n_threads: int = 4) -> Trace:
    """Random mixed READ/WRITE/APPEND trace (engine-invariant tests)."""
    rng = np.random.default_rng(seed)
    ops = rng.choice([int(OpType.READ), int(OpType.WRITE),
                      int(OpType.APPEND)], size=n)
    return Trace.build(
        op=ops, zone=rng.integers(0, n_zones, n),
        size=rng.choice([4 * KiB, 8 * KiB, 32 * KiB], n),
        issue=np.sort(rng.uniform(0, 1e5, n)),
        thread=rng.integers(0, n_threads, n), qd=np.full(n, qd))


# ---------------------------------------------------------------------------
# Hypothesis strategies
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    def io_trace_args():
        """(n, qd, seed) triples for :func:`random_io_trace`."""
        return st.tuples(st.integers(1, 200), st.integers(1, 8),
                         st.integers(0, 3))

    @st.composite
    def small_zns_specs(draw):
        """Small randomized geometries with ZNS-consistent invariants
        (cap <= size, active >= open, few zones so fills are cheap)."""
        max_open = draw(st.integers(2, 6))
        return ZNSDeviceSpec(
            zone_size_bytes=1 << 20,
            zone_cap_bytes=draw(st.sampled_from([1 << 18, 1 << 19])),
            num_zones=draw(st.integers(8, 48)),
            max_open_zones=max_open,
            max_active_zones=max_open + draw(st.integers(0, 4)),
        )

    def latency_profiles():
        """Calibrated parameter pytrees (§IV emulator profiles)."""
        return st.sampled_from([EMULATOR_PROFILES[n] for n in PROFILE_NAMES])

    def fleet_specs():
        """Fleet-grade device geometries (ZN540-scale variants)."""
        return st.sampled_from(SPEC_VARIANTS)

    @st.composite
    def mixed_workload_specs(draw, max_scale: int = 12,
                             with_mgmt: bool | None = None):
        """Randomly scaled :func:`mixed_workload` specs."""
        scale = draw(st.integers(2, max_scale))
        mgmt = draw(st.booleans()) if with_mgmt is None else with_mgmt
        return mixed_workload(scale, with_mgmt=mgmt)

    @st.composite
    def pool_workload_specs(draw, max_threads: int = 6):
        """Saturated server-pool workloads for the exactness fuzz suite:
        random thread count / QD and per-thread append sizes drawn from
        distinct service classes, so total concurrency lands far above
        ``append_parallelism`` and the pool chains must replay the
        greedy heterogeneous server assignment.  Optionally mixes in
        zone resets to queue the metadata engine too."""
        threads = draw(st.integers(2, max_threads))
        qd = draw(st.integers(1, 4))
        n = draw(st.integers(15, 50))
        wl = WorkloadSpec()
        for t in range(threads):
            size = draw(st.sampled_from([4, 8, 16, 64])) * KiB
            wl = wl.appends(n=n, size=size, qd=qd, zone=t * 4, nzones=4)
        if draw(st.booleans()):
            wl = wl.resets(n=max(n // 2, 4), occupancy=1.0,
                           nzones=max(n // 2, 4), io_ctx=OpType.APPEND,
                           zone=500)
        return wl

    def arrival_processes():
        """Every :mod:`repro.core.arrival` process kind, with sane
        parameter ranges (rates that keep a few-hundred-request stream
        inside ~1 s of simulated time)."""
        deterministic = st.one_of(
            st.builds(DeterministicRate,
                      every_us=st.floats(1.0, 500.0)),
            st.builds(DeterministicRate,
                      rate_per_s=st.floats(2e3, 1e6)))
        poisson = st.builds(PoissonArrivals,
                            rate_per_s=st.floats(2e3, 1e6),
                            seed=st.integers(0, 7))
        mmpp = st.builds(MarkovModulated,
                         rate_on_per_s=st.floats(1e4, 1e6),
                         rate_off_per_s=st.sampled_from([0.0, 1e3]),
                         mean_on_us=st.floats(100.0, 5e3),
                         mean_off_us=st.floats(100.0, 5e3),
                         seed=st.integers(0, 7),
                         start_on=st.booleans())
        replay = st.builds(
            lambda times: TraceReplay(times_us=tuple(times)),
            st.lists(st.floats(0.0, 1e5), min_size=400, max_size=400))
        return st.one_of(deterministic, poisson, mmpp, replay)

    @st.composite
    def open_loop_workload_specs(draw, max_streams: int = 3):
        """Mixed open-loop workloads: each stream gets its own arrival
        process and ``qd=0`` (pure open loop) or a small binding qd, so
        the differential suite exercises both the unbounded path and
        rate-limited closed loops."""
        n_streams = draw(st.integers(1, max_streams))
        wl = WorkloadSpec()
        for t in range(n_streams):
            op = draw(st.sampled_from(
                [OpType.READ, OpType.WRITE, OpType.APPEND]))
            wl = wl.stream(
                op, n=draw(st.integers(20, 120)),
                size=draw(st.sampled_from([4 * KiB, 16 * KiB])),
                qd=draw(st.sampled_from([0, 0, 2])),
                zone=t * 8, nzones=draw(st.integers(1, 8)),
                arrival=draw(arrival_processes()))
        if draw(st.booleans()):
            wl = wl.resets(n=8, occupancy=1.0, nzones=8, zone=400, qd=0,
                           io_ctx=OpType.READ,
                           arrival=draw(arrival_processes()))
        return wl

    @st.composite
    def allocation_requests(draw, spec: ZNSDeviceSpec):
        """A feasible list of (nbytes, stream, lifetime) allocations:
        total stays under half the device capacity so every policy can
        place them without reclaim."""
        cap = spec.zone_cap_bytes
        budget = spec.capacity_bytes // 2
        n = draw(st.integers(1, 24))
        out = []
        total = 0
        for _ in range(n):
            nbytes = draw(st.integers(1, 2 * cap))
            if total + nbytes > budget:
                break
            total += nbytes
            out.append((nbytes, draw(st.integers(0, 3)),
                        draw(st.one_of(st.none(), st.integers(0, 5)))))
        return out if out else [(cap // 2, 0, None)]
