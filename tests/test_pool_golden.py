"""Golden-trace regression for multi-class and jittered pool exactness.

Companion to ``tests/test_host_golden.py`` for the shapes the greedy
pool replay newly solves exactly: a heterogeneous (8 KiB + 64 KiB)
saturated append pool, jitter-free and jittered.  Each fixture under
``tests/golden/`` pins the built workload's digest and the **event
engine's** completion times, and the test asserts the vectorized
backend still reproduces them at the exactness-matrix tolerances — so
any regression of ``ChainProgram.exact`` shows up as a byte-visible
fixture diff in review, not a silently widened tolerance.

Regenerate after an *intentional* model change with::

    pytest tests/test_pool_golden.py --regen-golden
"""
import hashlib
import json
import pathlib

import numpy as np
import pytest

from repro.core import KiB, OpType, WorkloadSpec, ZNSDeviceSpec, ZnsDevice

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"

#: (case name, jitter, seed) pinned by a fixture each.
GOLDEN_CASES = (
    ("pool-multiclass", False, 0),
    ("pool-multiclass-jittered", True, 3),
    ("pool-reset-mixed", False, 0),
)

RTOL = {False: 1e-9, True: 1e-8}     # exactness-matrix tolerances


def _workload(case: str):
    wl = WorkloadSpec()
    for t in range(4):
        wl = wl.appends(n=50, size=8 * KiB, qd=4, zone=t * 4, nzones=4)
        wl = wl.appends(n=50, size=64 * KiB, qd=4, zone=t * 4, nzones=4)
    if case == "pool-reset-mixed":
        wl = wl.resets(n=20, occupancy=1.0, nzones=20,
                       io_ctx=OpType.APPEND, zone=500)
    return wl.build()


def _trace_digest(trace) -> str:
    h = hashlib.sha256()
    for field in ("op", "zone", "size", "issue", "thread", "qd",
                  "occupancy", "was_finished", "io_ctx"):
        h.update(np.ascontiguousarray(getattr(trace, field)).tobytes())
    return h.hexdigest()


def _compute(case: str, jitter: bool, seed: int) -> dict:
    trace = _workload(case)
    dev = ZnsDevice(ZNSDeviceSpec())
    res = dev.run(trace, backend="event", seed=seed, jitter=jitter)
    return {
        "case": case, "jitter": jitter, "seed": seed,
        "n_requests": len(trace),
        "workload_sha256": _trace_digest(trace),
        "complete_us": [float(c) for c in res.sim.complete],
    }


@pytest.mark.parametrize("case,jitter,seed", GOLDEN_CASES,
                         ids=lambda v: str(v))
def test_pool_golden_regression(request, case, jitter, seed):
    path = GOLDEN_DIR / f"{case}.json"
    got = _compute(case, jitter, seed)
    if request.config.getoption("--regen-golden"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        with open(path, "w") as f:
            json.dump(got, f, indent=0)
        pytest.skip(f"regenerated {path.name}")
    assert path.exists(), \
        f"missing golden fixture {path}; run pytest --regen-golden"
    with open(path) as f:
        want = json.load(f)
    assert got["workload_sha256"] == want["workload_sha256"], \
        "workload builder drifted: rebuilt trace differs from fixture"
    np.testing.assert_allclose(got["complete_us"], want["complete_us"],
                               rtol=1e-12)
    # the exactness claim: vectorized reproduces the pinned oracle times
    dev = ZnsDevice(ZNSDeviceSpec())
    vc = dev.run(_workload(case), backend="vectorized", seed=seed,
                 jitter=jitter)
    assert vc.exact is True and vc.order_stable is True
    np.testing.assert_allclose(vc.sim.complete,
                               np.asarray(want["complete_us"]),
                               rtol=RTOL[jitter], atol=1e-6)
