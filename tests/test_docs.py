"""Docs tree integrity: intra-repo markdown links resolve, docstring
examples run (doctest), and docs/observations.md stays in sync with the
observation registry.  CI's ``docs`` job runs exactly this module.
"""
import doctest
import importlib
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

MD_FILES = sorted(p for p in REPO.glob("**/*.md")
                  if not any(part.startswith(".") or part in
                             ("node_modules", "results", "related")
                             for part in p.relative_to(REPO).parts))

#: Public modules whose docstring examples must be runnable.
DOCTEST_MODULES = (
    "repro.core.arrival",
    "repro.core.chain_program",
    "repro.core.device",
    "repro.core.workload",
    "repro.core.latency",
    "repro.core.metrics",
    "repro.experiments",
    "repro.experiments.registry",
    "repro.experiments.runner",
    "repro.host.scenarios",
    "repro.cluster.codec",
    "repro.cluster.placement",
    "repro.cluster.cluster",
)

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def test_markdown_corpus_nonempty():
    names = {p.name for p in MD_FILES}
    assert {"README.md", "architecture.md", "observations.md",
            "api.md"} <= names


@pytest.mark.parametrize("md", MD_FILES, ids=lambda p: str(p.relative_to(REPO)))
def test_intra_repo_markdown_links_resolve(md):
    broken = []
    for target in _LINK.findall(md.read_text(encoding="utf-8")):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).resolve().exists():
            broken.append(target)
    assert not broken, f"{md}: broken relative links {broken}"


@pytest.mark.parametrize("module", DOCTEST_MODULES)
def test_docstring_examples_run(module):
    mod = importlib.import_module(module)
    res = doctest.testmod(mod, verbose=False,
                          optionflags=doctest.NORMALIZE_WHITESPACE)
    assert res.attempted > 0, f"{module}: no doctest examples found"
    assert res.failed == 0, f"{module}: {res.failed} doctest failures"


def test_exactness_matrix_doc_in_sync_with_benchmark():
    """docs/architecture.md's exactness-matrix table must cover every
    axis and tolerance the CI gate (benchmarks/exactness_matrix.py)
    actually enforces."""
    import sys
    sys.path.insert(0, str(REPO))
    from benchmarks.exactness_matrix import (
        LAYOUTS, TOL_JITTER_FREE, TOL_JITTERED, WORKLOADS)
    text = (REPO / "docs" / "architecture.md").read_text(encoding="utf-8")
    for token in (*WORKLOADS, *LAYOUTS):
        assert f"`{token}`" in text, \
            f"docs/architecture.md exactness matrix is missing `{token}`"
    for tol in (TOL_JITTER_FREE, TOL_JITTERED):
        tok = f"{tol:.0e}".replace("e-0", "e-")
        assert f"`{tok}`" in text, \
            f"docs/architecture.md is missing gate tolerance `{tok}`"


def test_observations_doc_in_sync_with_registry():
    from repro.experiments import all_experiments
    text = (REPO / "docs" / "observations.md").read_text(encoding="utf-8")
    for exp in all_experiments():
        assert exp.name in text, \
            f"docs/observations.md is missing registry entry {exp.name}"
        for knob in exp.knobs:
            assert knob in text, \
                f"docs/observations.md is missing {exp.name} knob {knob}"
        for t in exp.tests:
            assert t.split("::")[-1] in text, \
                f"docs/observations.md is missing {exp.name} test {t}"
    assert f"| #{len(all_experiments())} |" in text


def test_readme_quickstart_mentions_experiments_cli():
    text = (REPO / "README.md").read_text(encoding="utf-8")
    assert "python -m repro.experiments run --all" in text
    assert "docs/observations.md" in text


def test_solver_docs_in_sync_with_solve_stats_and_knobs():
    """docs/api.md must document every SolveStats field and the PR 10
    solver knobs exactly as the code exposes them; architecture.md must
    carry the matching solver-section narrative."""
    import dataclasses
    import inspect

    from repro.core import SolveStats, solve_program_windowed
    from repro.cluster.capacity import plan_capacity

    api = (REPO / "docs" / "api.md").read_text(encoding="utf-8")
    arch = (REPO / "docs" / "architecture.md").read_text(encoding="utf-8")

    for f in dataclasses.fields(SolveStats):
        assert f.name in api, f"docs/api.md is missing SolveStats.{f.name}"
    for token in ("last_solve_stats", "solve_stats",
                  'fixpoint="windowed"', "solve_program_windowed",
                  "window_program", "n_windows", "window_events",
                  "warm_ladder=True", "--warm-ladder", "warm_hits",
                  "unjustified_slots"):
        assert token in api, f"docs/api.md is missing {token}"
    # the documented knobs exist with those exact names
    sig = inspect.signature(solve_program_windowed)
    assert {"n_windows", "window_events"} <= set(sig.parameters)
    assert "warm_ladder" in inspect.signature(plan_capacity).parameters

    for token in ("Active-set sweeps", "window_program",
                  "solve_program_windowed", "warm_ladder=True",
                  "SolveStats", "unjustified_slots", "warm_hits"):
        assert token in arch, f"docs/architecture.md is missing {token}"
