"""DeviceFleet batched simulation: equivalence with per-device loops
(hypothesis property + 16-device acceptance case), workload sharding,
parameter-pytree profiles, simulated §IV fidelity, batched scans, and the
backend-registry hardening + RunResult memoization satellites."""
import warnings

import numpy as np
import pytest

from repro.core import (
    DeviceFleet, KiB, LatencyModel, LatencyParams,
    OpType, RunResult, WorkloadSpec, ZnsDevice, ZNSDeviceSpec,
    available_backends, register_backend, stack_latency_params,
    unregister_backend, unstack_latency_params,
    zone_sequential_completions, zone_sequential_completions_batched,
)
from repro.core.device import _resolve_backend
from repro.core.emulator_models import (
    ALL_MODELS, EMULATOR_PROFILES, FIDELITY_MATRIX, simulated_fidelity,
)
from strategies import (
    PROFILE_NAMES, SPEC_VARIANTS, fleet_members as _members,
    mixed_workload as _mixed,
)


def _assert_fleet_equals_loop(members, workloads, backend, *, seed=0,
                              jitter=False):
    fleet = DeviceFleet(members)
    fres = fleet.run(workloads, backend=backend, seed=seed, jitter=jitter)
    assert fres.backend == backend
    for i, (spec, params) in enumerate(members):
        dev = ZnsDevice(spec, lat=LatencyModel(spec, params))
        wl = workloads[i] if isinstance(workloads, (list, tuple)) \
            else workloads
        ref = dev.run(wl, backend=backend, seed=seed + i, jitter=jitter)
        np.testing.assert_array_equal(fres[i].sim.service, ref.sim.service)
        np.testing.assert_allclose(fres[i].sim.complete, ref.sim.complete,
                                   rtol=1e-9, atol=1e-6)
        np.testing.assert_allclose(fres[i].sim.start, ref.sim.start,
                                   rtol=1e-9, atol=1e-6)
    return fres


# -- acceptance: 16 heterogeneous devices, all op types, both backends ---------
@pytest.mark.parametrize("backend", ["event", "vectorized"])
def test_fleet_16_heterogeneous_matches_loop(backend):
    members = _members(16)
    wls = [_mixed(20 + 3 * i) for i in range(16)]
    _assert_fleet_equals_loop(members, wls, backend, seed=3, jitter=True)


def test_fleet_obs12_obs13_couplings_preserved():
    # Obs#13: inflated resets on the 'ours' member; Obs#12: the same I/O
    # stream is undisturbed by concurrent resets in a fleet run.
    members = [(ZNSDeviceSpec(), EMULATOR_PROFILES["ours"])] * 2
    io = WorkloadSpec().writes(n=800, qd=4, zone=100)
    both = (WorkloadSpec()
            .resets(n=60, occupancy=1.0, nzones=50, io_ctx=OpType.WRITE,
                    thread=9)
            .writes(n=800, qd=4, zone=100))
    fleet = DeviceFleet(members)
    quiet, loud = fleet.run([io, both], backend="vectorized", jitter=False)
    wmask = loud.trace.op == int(OpType.WRITE)
    np.testing.assert_allclose(loud.sim.complete[wmask], quiet.sim.complete,
                               rtol=1e-12)   # Obs#12 (seeds differ: jitter off)
    iso = fleet.run([WorkloadSpec().resets(n=60, occupancy=1.0, nzones=50)] * 2,
                    backend="vectorized", jitter=False)[0]
    ratio = (loud.latency_stats(OpType.RESET).mean_us
             / iso.latency_stats(OpType.RESET).mean_us)
    assert ratio == pytest.approx(1.7842, rel=1e-3)   # Obs#13 anchor


# -- hypothesis property: fleet == loop over random heterogeneous fleets -------
from strategies import HAVE_HYPOTHESIS

if HAVE_HYPOTHESIS:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    from strategies import fleet_specs, latency_profiles, \
        mixed_workload_specs

    @given(st.lists(st.tuples(fleet_specs(), latency_profiles(),
                              mixed_workload_specs()),
                    min_size=1, max_size=5),
           st.integers(0, 1000), st.booleans(),
           st.sampled_from(["event", "vectorized"]))
    @settings(max_examples=12, deadline=None)
    def test_fleet_equals_loop_property(devices, seed, jitter, backend):
        members = [(spec, params) for spec, params, _ in devices]
        wls = [wl for _, _, wl in devices]
        _assert_fleet_equals_loop(members, wls, backend, seed=seed % 97,
                                  jitter=jitter)


# -- workload sharding ---------------------------------------------------------
def test_shard_round_robin_assigns_whole_streams():
    wl = _mixed(10)
    shards = wl.shard(3, policy="round_robin")
    assert len(shards) == 3
    assert sum(len(s) for s in shards) == len(wl)
    ops = [s.streams[0].op for s in shards]
    assert ops == [OpType.WRITE, OpType.READ, OpType.APPEND]


def test_shard_replicate_and_split():
    wl = WorkloadSpec().writes(n=103, qd=2)
    for s in wl.shard(4, policy="replicate"):
        assert s.streams[0].n == 103
    split = wl.shard(4, policy="split")
    assert [s.streams[0].n for s in split] == [26, 26, 26, 25]


def test_shard_split_preserves_sweep_request_counts():
    wl = WorkloadSpec().reset_sweep((0.25, 1.0), n_per_level=10, pause_us=0)
    total = len(wl.build())
    shards = wl.shard(4, policy="split")
    assert sum(len(s.build(allow_empty=True)) for s in shards) == total
    assert [s.streams[0].n_per_level for s in shards] == [3, 3, 2, 2]


def test_shard_idle_devices_get_empty_specs():
    wl = WorkloadSpec().writes(n=50)
    shards = wl.shard(3, policy="round_robin")
    assert [len(s) for s in shards] == [1, 0, 0]
    fres = DeviceFleet.homogeneous(3).run(wl, backend="event")
    assert [len(r) for r in fres] == [50, 0, 0]
    assert fres.completion_us[1] == 0.0


def test_shard_bad_policy_rejected():
    with pytest.raises(ValueError, match="policy"):
        WorkloadSpec().writes(n=4).shard(2, policy="zigzag")
    with pytest.raises(ValueError, match="positive"):
        WorkloadSpec().writes(n=4).shard(0)


# -- parameter pytrees ---------------------------------------------------------
def test_stack_unstack_latency_params_roundtrip():
    ps = [EMULATOR_PROFILES[n] for n in PROFILE_NAMES]
    stacked = stack_latency_params(ps)
    assert stacked.io_svc_us.shape == (3,) + ps[0].io_svc_us.shape
    for i, p in enumerate(ps):
        back = unstack_latency_params(stacked, i)
        for name, val in p.fields():
            np.testing.assert_array_equal(getattr(back, name), val)


def test_fleet_stacked_params_leading_axis():
    fleet = DeviceFleet.from_profiles(PROFILE_NAMES)
    stacked = fleet.stacked_params()
    assert stacked.reset_us_table.shape[0] == 3


def test_latency_model_wraps_params():
    lm = LatencyModel()
    assert isinstance(lm.params, LatencyParams)
    assert float(lm.io_service_us(OpType.WRITE, 4 * KiB)) == \
        pytest.approx(11.36, abs=0.01)
    assert ZnsDevice().params is ZnsDevice().lat.params


def test_emulator_shims_delegate_to_profiles():
    from repro.core.latency import io_service_us
    for name, model in ALL_MODELS.items():
        p = EMULATOR_PROFILES[name]
        np.testing.assert_allclose(
            np.asarray(model.io_service_us(OpType.WRITE, 8 * KiB)),
            np.asarray(io_service_us(p, OpType.WRITE, 8 * KiB)))


# -- §IV fidelity from simulation ----------------------------------------------
@pytest.mark.parametrize("name", PROFILE_NAMES)
def test_fidelity_matrix_derived_from_simulation(name):
    assert simulated_fidelity(name) == FIDELITY_MATRIX[name]


@pytest.mark.slow
@pytest.mark.parametrize("name", PROFILE_NAMES)
def test_fidelity_matrix_derived_on_vectorized_backend(name):
    assert simulated_fidelity(name, backend="vectorized") == \
        FIDELITY_MATRIX[name]


def test_profiles_run_through_batched_path():
    fleet = DeviceFleet.from_profiles(PROFILE_NAMES)
    res = fleet.run(_mixed(10), backend="vectorized", policy="replicate",
                    jitter=False)
    ours, nvmevirt, femu = res                # PROFILE_NAMES order
    # FEMU is DRAM-fast; NVMeVirt models reads correctly but resets flat.
    assert femu.latency_stats(OpType.READ).mean_us < 3.0
    assert nvmevirt.latency_stats(OpType.READ).mean_us == pytest.approx(
        ours.latency_stats(OpType.READ).mean_us, rel=0.05)
    assert nvmevirt.latency_stats(OpType.RESET).p95_us == pytest.approx(
        3500.0, rel=1e-6)
    assert ours.latency_stats(OpType.RESET).mean_us > 10_000


# -- batched scans -------------------------------------------------------------
def test_batched_scan_matches_python_oracle():
    rng = np.random.default_rng(1)
    B, n = 7, 513
    issue = np.sort(rng.uniform(0, 1e5, (B, n)), axis=1)
    svc = rng.uniform(1, 300, (B, n))
    seg = rng.uniform(size=(B, n)) < 0.03
    seg[:, 0] = True
    out = zone_sequential_completions_batched(issue, svc, seg,
                                              backend="numpy")
    want = zone_sequential_completions_batched(issue, svc, seg,
                                               backend="python")
    np.testing.assert_allclose(out, want, rtol=1e-12)


def test_batched_scan_rows_match_1d_scan():
    rng = np.random.default_rng(2)
    B, n = 4, 1000
    issue = np.sort(rng.uniform(0, 1e4, (B, n)), axis=1)
    svc = rng.uniform(0.5, 40, (B, n))
    seg = rng.uniform(size=(B, n)) < 0.05
    out = zone_sequential_completions_batched(issue, svc, seg,
                                              backend="numpy")
    for b in range(B):
        np.testing.assert_allclose(
            out[b], zone_sequential_completions(issue[b], svc[b], seg[b],
                                                backend="numpy"), rtol=1e-12)


def test_fleet_sequential_completions_ragged():
    fleet = DeviceFleet.homogeneous(3)
    issues = [np.arange(n, dtype=float) * 10 for n in (5, 9, 2)]
    svcs = [np.full(len(i), 3.0) for i in issues]
    segs = [np.r_[True, np.zeros(len(i) - 1, bool)] for i in issues]
    outs = fleet.sequential_completions(issues, svcs, segs)
    for i, o in enumerate(outs):
        assert len(o) == len(issues[i])
        np.testing.assert_allclose(
            o, zone_sequential_completions(issues[i], svcs[i], segs[i],
                                           backend="numpy"))


# -- satellite: backend registry hardening -------------------------------------
def test_register_backend_collision_warns():
    def fake(trace, spec, lat, *, seed=0, jitter=True, **_):
        raise AssertionError("never called")
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            register_backend("collide-test", fake)
            assert not w
            register_backend("collide-test", fake)       # same fn: silent
            assert not w
            register_backend("collide-test", lambda *a, **k: None)
            assert len(w) == 1 and "already registered" in str(w[0].message)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            register_backend("collide-test", fake, replace=True)
            assert not w
    finally:
        unregister_backend("collide-test")
    assert "collide-test" not in available_backends()


def test_resolve_auto_tolerates_mutated_registry():
    from repro.core.device import _BACKENDS
    tr = WorkloadSpec().writes(n=4).build()
    big = WorkloadSpec().writes(n=9000).build()
    saved = dict(_BACKENDS)
    try:
        del _BACKENDS["vectorized"]
        assert _resolve_backend("auto", big) == "event"
        _BACKENDS.clear()
        _BACKENDS["thirdparty"] = saved["event"]
        assert _resolve_backend("auto", tr) == "thirdparty"
        _BACKENDS.clear()
        with pytest.raises(KeyError, match="no simulation backends"):
            _resolve_backend("auto", tr)
    finally:
        _BACKENDS.clear()
        _BACKENDS.update(saved)
    assert ZnsDevice().run(tr, backend="auto").backend == "event"


# -- satellite: RunResult stats memoization ------------------------------------
def test_latency_stats_memoized_per_key():
    res = ZnsDevice().run(WorkloadSpec().writes(n=200, qd=2).reads(n=100),
                          jitter=False)
    a = res.latency_stats(OpType.WRITE)
    assert res.latency_stats(OpType.WRITE) is a          # cached object
    assert res.latency_stats(OpType.WRITE, from_issue=True) is not a
    assert res.latency_stats() is res.latency_stats()
    assert res.per_op_stats()[OpType.WRITE] is a         # shares the cache
    with pytest.raises(ValueError, match="no APPEND"):
        res.latency_stats(OpType.APPEND)


def test_run_result_cache_excluded_from_repr():
    res = ZnsDevice().run(WorkloadSpec().writes(n=8), jitter=False)
    res.latency_stats()
    assert isinstance(res, RunResult)
    assert "_stats_cache" not in repr(res)


# -- review regressions --------------------------------------------------------
def test_latency_params_eq_and_hash():
    from repro.core import zn540_params
    a, b = zn540_params(), zn540_params()
    assert a == b and hash(a) == hash(b)
    assert a != EMULATOR_PROFILES["femu"]
    assert LatencyModel() == LatencyModel()
    assert {LatencyModel(): 1}[LatencyModel()] == 1   # dict-keyable


def test_pressure_backend_device_type_checked():
    from repro.core import ConvDevice
    with pytest.raises(TypeError, match="needs a ConvDevice"):
        ZnsDevice().run_write_pressure(rate_mibs=1.0, backend="conventional")
    with pytest.raises(TypeError, match="needs a ZnsDevice"):
        ConvDevice().run_write_pressure(rate_mibs=1.0, backend="zns")


def test_fleet_honors_replaced_vectorized_backend():
    from repro.core import SimResult
    calls = []

    def fake(trace, spec, lat, *, seed=0, jitter=True, **_):
        calls.append(seed)
        z = np.zeros(len(trace))
        return SimResult(start=z, complete=z.copy(), service=z.copy())

    from repro.core.device import _BACKENDS
    saved = _BACKENDS["vectorized"]
    try:
        register_backend("vectorized", fake, replace=True)
        fleet = DeviceFleet.homogeneous(3)
        res = fleet.run(WorkloadSpec().writes(n=30), backend="vectorized",
                        policy="replicate")
        assert calls == [0, 1, 2]          # per-device loop of the override
        assert res.backend == "vectorized"
    finally:
        register_backend("vectorized", saved, replace=True)


# -- pressure backends ---------------------------------------------------------
def test_pressure_backends_share_result_type():
    from repro.core import ConvDevice, PressureResult
    from repro.core.device import available_pressure_backends
    assert {"zns", "conventional"} <= set(available_pressure_backends())
    zns = ZnsDevice().run_write_pressure(rate_mibs=800.0, duration_s=5)
    conv = ConvDevice().run_write_pressure(rate_mibs=800.0, duration_s=5)
    assert isinstance(zns, PressureResult)
    assert isinstance(conv, PressureResult)
    assert conv.write_amplification >= 1.0
    with pytest.raises(KeyError, match="pressure backend"):
        ZnsDevice().run_write_pressure(rate_mibs=1.0, backend="nope")


# -- fleet aggregates ----------------------------------------------------------
def test_fleet_run_result_aggregates():
    fleet = DeviceFleet.homogeneous(4)
    res = fleet.run(WorkloadSpec().writes(n=500, qd=4),
                    policy="replicate", backend="event", jitter=False)
    assert len(res) == 4
    assert res.total_iops == pytest.approx(4 * res[0].iops, rel=1e-6)
    pooled = res.latency_stats(OpType.WRITE)
    assert pooled.n == 4 * 500
    assert (res.completion_us > 0).all()


# -- shard edge cases (more devices than streams/requests) ---------------------
def test_shard_split_more_devices_than_requests_builds_cleanly():
    wl = WorkloadSpec().writes(n=3, qd=1)
    shards = wl.shard(8, policy="split")
    assert len(shards) == 8
    # remainder shards are empty but still buildable (no allow_empty needed)
    assert [len(s.build()) for s in shards] == [1, 1, 1, 0, 0, 0, 0, 0]
    fres = DeviceFleet.homogeneous(8).run(wl, policy="split", backend="event",
                                          jitter=False)
    assert [len(r) for r in fres] == [1, 1, 1, 0, 0, 0, 0, 0]
    assert fres.total_iops >= 0.0


def test_shard_round_robin_empty_shards_build_cleanly():
    shards = WorkloadSpec().writes(n=50).shard(4, policy="round_robin")
    assert [len(s.build()) for s in shards] == [50, 0, 0, 0]


def test_shard_split_zero_length_remainder_of_sweep_streams():
    wl = WorkloadSpec().reset_sweep((0.5, 1.0), n_per_level=3, pause_us=0)
    shards = wl.shard(8, policy="split")
    built = [s.build() for s in shards]
    # 3 requests per occupancy level split across 8 devices: 3 devices get
    # one request per level, the rest lower to empty traces
    assert [len(t) for t in built] == [2, 2, 2, 0, 0, 0, 0, 0]
    total = sum(len(t) for t in built)
    assert total == len(wl.build())


def test_shard_split_drops_zero_n_streams_but_keeps_totals():
    wl = WorkloadSpec().writes(n=0).reads(n=5)
    shards = wl.shard(3, policy="split")
    assert sum(len(s.build()) for s in shards) == 5
    assert all(all(st.n > 0 for st in s.streams) for s in shards)


def test_unsharded_empty_spec_still_raises():
    with pytest.raises(ValueError, match="empty WorkloadSpec"):
        WorkloadSpec().build()


def test_fleet_run_with_explicit_seeds_matches_loop():
    wl = WorkloadSpec().writes(n=200, qd=2)
    seeds = [11, 29, 47]
    fleet = DeviceFleet.homogeneous(3)
    fres = fleet.run(wl, policy="replicate", backend="vectorized",
                     seeds=seeds, jitter=True)
    for i, seed in enumerate(seeds):
        solo = ZnsDevice().run(wl, backend="vectorized", seed=seed,
                               jitter=True)
        np.testing.assert_allclose(fres[i].sim.complete, solo.sim.complete,
                                   rtol=1e-9, atol=1e-6)
    with pytest.raises(ValueError, match="seeds"):
        fleet.run(wl, policy="replicate", seeds=[1, 2])


# -- fleet sweep-budget warning dedupe + SolveStats (PR 10 satellites) --------
def _budget_msgs(caught):
    return [str(w.message) for w in caught
            if issubclass(w.category, RuntimeWarning)
            and "sweep budget" in str(w.message)]


def test_fleet_vectorized_budget_warning_fires_once_with_context():
    fleet = DeviceFleet.homogeneous(3)
    wl = (WorkloadSpec().writes(n=2000, qd=4, zone=7)
          .resets(n=100, occupancy=1.0, nzones=50, io_ctx=OpType.WRITE))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        fres = fleet.run(wl, policy="replicate", backend="vectorized",
                         jitter=False, sweeps=1)
    msgs = _budget_msgs(caught)
    assert len(msgs) == 1                       # one per fleet call, not per device
    assert "sweeps_used=1" in msgs[0] and "budget=1" in msgs[0]
    assert not fres.converged


def test_fleet_loop_path_dedupes_per_device_budget_warnings():
    # break the registry-identity check so DeviceFleet.run takes the
    # per-device loop: each device's solve warns, the fleet collapses
    # them into one aggregated message naming the offending indices
    import repro.core.device as device_mod
    orig = device_mod._BACKENDS["vectorized"]
    device_mod._BACKENDS["vectorized"] = \
        lambda *a, **k: orig(*a, **k)
    try:
        fleet = DeviceFleet.homogeneous(3)
        wl = (WorkloadSpec().writes(n=2000, qd=4, zone=7)
              .resets(n=100, occupancy=1.0, nzones=50, io_ctx=OpType.WRITE))
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            fres = fleet.run(wl, policy="replicate", backend="vectorized",
                             jitter=False, sweeps=1)
    finally:
        device_mod._BACKENDS["vectorized"] = orig
    msgs = _budget_msgs(caught)
    assert len(msgs) == 1
    assert "indices [0, 1, 2]" in msgs[0]
    assert "sweeps_used=[1, 1, 1]" in msgs[0] and "budget=1" in msgs[0]
    assert not fres.converged


def test_fleet_budget_warning_names_moving_entries():
    # a genuinely under-converged iterate (issue + svc lower bound) maps
    # its moving slots back to fleet entry indices
    from repro.core import chain_program as cp
    from repro.core.fleet import _warn_fleet_budget
    traces = [WorkloadSpec().writes(n=500, qd=4, zone=z).build()
              for z in (0, 1)]
    specs = [ZNSDeviceSpec()] * 2
    lats = [LatencyModel()] * 2
    program = cp.compile_fleet_program(
        traces, specs, [l.params for l in lats], jitter=False,
        seeds=[0, 1])
    svc = program.svc0_flat
    comp = program.issue_flat + svc             # one-sweep lower bound
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        _warn_fleet_budget(program, svc, comp, 1, 1)
    msgs = _budget_msgs(caught)
    assert len(msgs) == 1
    assert "entries (indices [0, 1])" in msgs[0]


def test_solve_stats_on_run_results():
    dev = ZnsDevice()
    wl = WorkloadSpec().writes(n=9000, qd=4, zone=3)
    res = dev.run(wl, backend="vectorized", jitter=False)
    st = res.solve_stats
    assert st is not None and st.converged and st.sweeps >= 1
    assert st.driver == "loop"
    assert len(st.active_blocks) == st.sweeps
    assert len(st.residuals) == st.sweeps
    # trajectory is monotone in work: final sweep is a verification pass
    assert st.residuals[-1] == 0.0
    assert st.to_json()["sweeps"] == st.sweeps
    # the event engine has no solver
    ev = dev.run(WorkloadSpec().writes(n=10), backend="event")
    assert ev.solve_stats is None

    fleet = DeviceFleet.homogeneous(2)
    fres = fleet.run(wl, policy="replicate", backend="vectorized",
                     jitter=False)
    assert fres.solve_stats is not None and fres.solve_stats.converged
    assert fres[0].solve_stats is fres.solve_stats
