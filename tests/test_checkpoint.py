"""ZonedCheckpointStore: roundtrip, atomic commit, checksums, gc, and the
paper-recommendation behaviours of the placement planner."""
import json
import os

import numpy as np
import pytest

import jax

from repro.core import MiB, ZNSDeviceSpec
from repro.runtime import ZonedCheckpointStore
from repro.runtime.zns_store import ZnsHostDevice

SMALL_SPEC = ZNSDeviceSpec(zone_size_bytes=8 * MiB, zone_cap_bytes=4 * MiB,
                           num_zones=64, max_open_zones=6,
                           max_active_zones=8)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w1": rng.standard_normal((8, 16)).astype(np.float32),
        "nested": {"w2": rng.standard_normal((4, 4, 4)).astype(np.float32)},
        "scalar": np.float32(3.5),
    }


def test_save_restore_roundtrip(tmp_path):
    store = ZonedCheckpointStore(str(tmp_path), n_hosts=4, spec=SMALL_SPEC,
                                 stripe_bytes=64 * 1024)
    tree = _tree()
    out = store.save(10, tree)
    assert out["wall_seconds"] > 0
    restored, manifest = store.restore(10, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 10


def test_atomic_commit_no_tmp_left(tmp_path):
    store = ZonedCheckpointStore(str(tmp_path), n_hosts=2, spec=SMALL_SPEC)
    store.save(1, _tree())
    names = os.listdir(tmp_path)
    assert "step_00000001" in names
    assert not any(n.endswith(".tmp") for n in names)
    assert store.latest_step() == 1


def test_checksum_detects_corruption(tmp_path):
    store = ZonedCheckpointStore(str(tmp_path), n_hosts=2, spec=SMALL_SPEC)
    store.save(3, _tree())
    victim = os.path.join(str(tmp_path), "step_00000003", "host_00001.npz")
    with open(victim, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(IOError, match="checksum"):
        store.restore(3, _tree())


def test_gc_resets_zones_and_removes_old(tmp_path):
    store = ZonedCheckpointStore(str(tmp_path), n_hosts=1, spec=SMALL_SPEC)
    for step in (1, 2, 3):
        store.save(step, _tree(step))
    gc_s = store.gc(keep_last=1)
    left = sorted(n for n in os.listdir(tmp_path) if n.startswith("step"))
    assert left == ["step_00000003"]
    assert gc_s >= 0.0


def test_planner_bin_packs_and_avoids_finish():
    dev = ZnsHostDevice(0, SMALL_SPEC, stripe_bytes=256 * 1024)
    payload = int(2.5 * SMALL_SPEC.zone_cap_bytes)
    entries = dev.plan(payload)
    # exactly fills zones in order: cap, cap, half
    assert [e.nbytes for e in entries] == [
        SMALL_SPEC.zone_cap_bytes, SMALL_SPEC.zone_cap_bytes,
        payload - 2 * SMALL_SPEC.zone_cap_bytes]
    dev.apply_writes(entries)
    # no finish was needed: two FULL (filled) zones + one open partial
    states = [dev.zm.state(e.zone).name for e in entries]
    assert states[0] == "FULL" and states[1] == "FULL"
    assert states[2] in ("IMPLICIT_OPEN", "EXPLICIT_OPEN")
    # a second payload reuses the partial zone first (R3)
    entries2 = dev.plan(SMALL_SPEC.zone_cap_bytes)
    assert entries2[0].zone == entries[2].zone
    assert entries2[0].offset == dev.zm.write_pointer(entries[2].zone)


def test_paper_faithful_policy_beats_naive_small_io():
    fast = ZnsHostDevice(0, stripe_bytes=1 * MiB, append_qd=4)
    slow = ZnsHostDevice(1, stripe_bytes=4 * 1024, append_qd=1)
    nbytes = 512 * MiB
    t_fast, _ = fast.simulate_payload_write(nbytes)
    t_slow, _ = slow.simulate_payload_write(nbytes)
    assert t_fast < t_slow / 3          # R2: >=8KiB requests, QD4


def test_restore_after_host_failure_raises_without_redundancy(tmp_path):
    store = ZonedCheckpointStore(str(tmp_path), n_hosts=3, spec=SMALL_SPEC)
    store.save(5, _tree())
    with pytest.raises(IOError, match="host 1"):
        store.restore(5, _tree(), failed_hosts=(1,))


def test_manifest_records_zone_placement(tmp_path):
    store = ZonedCheckpointStore(str(tmp_path), n_hosts=2, spec=SMALL_SPEC)
    store.save(7, _tree())
    with open(os.path.join(str(tmp_path), "step_00000007",
                           "manifest.json")) as f:
        manifest = json.load(f)
    for h in ("0", "1"):
        info = manifest["hosts"][h]
        assert info["bytes"] > 0
        assert all(e["zone"] >= 1 for e in info["zones"])  # zone 0 = meta
