"""Per-architecture smoke tests on reduced configs: one forward/train
step on CPU asserting output shapes + no NaNs, plus a decode step."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import models as M
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.optim import AdamWConfig
from repro.train import TrainState, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    if cfg.num_codebooks > 1:
        tokens = jax.random.randint(KEY, (b, s, cfg.num_codebooks), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision_stub":
        batch["frontend_inputs"] = jax.random.normal(
            KEY, (b, cfg.num_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = M.forward(cfg, params, batch["tokens"],
                            batch.get("frontend_inputs"))
    b, s = batch["tokens"].shape[:2]
    if cfg.num_codebooks > 1:
        assert logits.shape == (b, s, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    state = TrainState.create(cfg, KEY)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=0))
    new_state, metrics = jax.jit(step)(state, _batch(cfg))
    assert int(new_state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(new_state.params)[0]
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_finite(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, KEY)
    b, s = 2, 64
    cache = M.init_cache(cfg, b, s)
    if cfg.num_codebooks > 1:
        tok = jax.random.randint(KEY, (b, cfg.num_codebooks), 0,
                                 cfg.vocab_size)
    else:
        tok = jax.random.randint(KEY, (b,), 0, cfg.vocab_size)
    logits, new_cache = M.decode_step(cfg, params, cache, tok, jnp.int32(0))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_param_counts_match_published_sizes():
    expected_b = {
        "tinyllama-1.1b": (1.0, 1.2), "qwen3-4b": (3.8, 4.6),
        "qwen3-8b": (7.5, 8.5), "llama3-405b": (400, 412),
        "arctic-480b": (465, 490), "qwen2-moe-a2.7b": (13.5, 15.0),
        "mamba2-370m": (0.33, 0.42), "internvl2-26b": (19, 21),
        "musicgen-large": (3.0, 3.5), "recurrentgemma-9b": (9.0, 10.2),
    }
    for arch, (lo, hi) in expected_b.items():
        n = M.count_params(get_config(arch)) / 1e9
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    cfg = get_config("qwen2-moe-a2.7b")
    act = M.count_active_params(cfg) / 1e9
    assert 2.2 <= act <= 3.2      # "A2.7B"


def test_microbatched_train_step_matches_single():
    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"),
                              remat="none")
    state = TrainState.create(cfg, KEY)
    batch = _batch(cfg, b=4, s=32)
    s1, m1 = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=0)))(
        state, batch)
    state2 = TrainState.create(cfg, KEY)
    s2, m2 = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=0),
                                     microbatches=2))(state2, batch)
    # same data, same init -> losses agree; grads averaged -> params close
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    p1 = np.concatenate([np.asarray(x, np.float32).ravel()
                         for x in jax.tree.leaves(s1.params)])
    p2 = np.concatenate([np.asarray(x, np.float32).ravel()
                         for x in jax.tree.leaves(s2.params)])
    np.testing.assert_allclose(p1, p2, atol=5e-4)
