"""ChainProgram compiler: lowering, pop-order refinement, the fused
fixpoint solvers, program caching, convergence diagnostics, and the
event-vs-fused equivalence the compiler newly guarantees on saturated
multi-thread append pools (the documented PR 4 gap)."""
import warnings

import numpy as np
import pytest

from repro.core import (
    ChainProgram, DeviceFleet, KiB, MiB, OpType, WorkloadSpec, ZnsDevice,
    ZNSDeviceSpec, clear_program_cache, compile_fleet_program,
    compile_program, program_cache_info, solve_program,
)
from repro.core.chain_program import DEFAULT_REFINE
from repro.core.device import AUTO_VECTORIZED_MIN
from repro.core.engine import (
    _simulate_vectorized_unfused, compute_service_times, simulate,
)
from strategies import HAVE_HYPOTHESIS

SPEC = ZNSDeviceSpec()


def _assert_equivalent(wl, *, spec=None, jitter=False, seed=3, rtol=1e-9,
                       **opts):
    spec = spec if spec is not None else SPEC
    dev = ZnsDevice(spec)
    tr = wl.build() if isinstance(wl, WorkloadSpec) else wl
    ev = dev.run(tr, backend="event", seed=seed, jitter=jitter)
    vc = dev.run(tr, backend="vectorized", seed=seed, jitter=jitter, **opts)
    np.testing.assert_allclose(vc.sim.service, ev.sim.service, rtol=1e-12)
    np.testing.assert_allclose(vc.sim.complete, ev.sim.complete, rtol=rtol,
                               atol=1e-6)
    np.testing.assert_allclose(vc.sim.start, ev.sim.start, rtol=rtol,
                               atol=1e-6)
    return ev, vc


def _append_pool_workload(threads=8, qd=4, n=400, size=8 * KiB):
    """Saturated multi-thread append pool (Obs#5-#7 shape): total
    concurrency threads*qd far above append_parallelism=2."""
    wl = WorkloadSpec()
    for t in range(threads):
        wl = wl.appends(n=n, size=size, qd=qd, zone=t * 4, nzones=4)
    return wl


# -- the closed gap: saturated multi-thread append pools ----------------------
@pytest.mark.parametrize("threads,qd", [(2, 4), (4, 1), (6, 2), (8, 4)])
def test_equiv_saturated_multithread_append_pool(threads, qd):
    _assert_equivalent(_append_pool_workload(threads=threads, qd=qd))


def test_equiv_mixed_reset_io_with_saturated_appends():
    wl = (_append_pool_workload(threads=4, qd=4)
          .resets(n=40, occupancy=1.0, nzones=40, io_ctx=OpType.APPEND,
                  zone=500))
    _assert_equivalent(wl)


def test_equiv_append_pool_with_reads_alongside():
    wl = (_append_pool_workload(threads=6, qd=2)
          .reads(n=800, size=4 * KiB, qd=4, zone=400, nzones=64))
    _assert_equivalent(wl)


def test_unfused_sweep_loop_misses_the_pool_gap():
    """The pre-compiler per-chain sweep loop (issue-ordered pools) is
    measurably wrong on the same trace — the compiler's refinement is
    what closes the gap, not a test artifact."""
    tr = _append_pool_workload().build()
    ev = simulate(tr, SPEC, seed=3, jitter=False)
    old = _simulate_vectorized_unfused(tr, SPEC, seed=3, jitter=False)
    rel = np.max(np.abs(old.complete - ev.complete)
                 / np.maximum(ev.complete, 1.0))
    assert rel > 1.0   # grossly off before the refactor


def test_program_exactness_flag():
    prog = compile_program(_append_pool_workload().build(), SPEC,
                           ZnsDevice(SPEC).lat, cache=False)
    assert prog.exact and prog.order_stable
    assert prog.multiclass_pools == ()
    # heterogeneous service classes in a saturated pool: the greedy
    # replay keeps the program exact; multiclass_pools stays as metadata
    het = (WorkloadSpec()
           .appends(n=300, size=8 * KiB, qd=4, zone=0)
           .appends(n=300, size=64 * KiB, qd=4, zone=8)).build()
    prog2 = compile_program(het, SPEC, ZnsDevice(SPEC).lat, cache=False)
    assert prog2.exact and prog2.order_stable
    assert prog2.unstable_pools == ()
    assert "append_pool" in prog2.multiclass_pools
    _assert_equivalent(het)


def test_refine_zero_warns_with_pool_labels_and_surfaces():
    """refine=0 is the budget-exhaustion path: the warning names the
    affected pools and the program records them for diagnostics."""
    het = (WorkloadSpec()
           .appends(n=100, size=8 * KiB, qd=4, zone=0)
           .appends(n=100, size=64 * KiB, qd=4, zone=8)).build()
    with pytest.warns(RuntimeWarning, match=r"refine=0.*append_pool"):
        prog = compile_program(het, SPEC, ZnsDevice(SPEC).lat,
                               cache=False, refine=0)
    assert not prog.exact and not prog.order_stable
    assert any("append_pool" in p for p in prog.unstable_pools)
    # ...and the flags surface on RunResult, not just the program
    dev = ZnsDevice(SPEC)
    with pytest.warns(RuntimeWarning, match=r"refine=0"):
        res = dev.run(het, backend="vectorized", jitter=False, refine=0)
    assert res.exact is False and res.order_stable is False
    assert any("append_pool" in p for p in res.unstable_pools)
    ok = dev.run(het, backend="vectorized", jitter=False)
    assert ok.exact is True and ok.order_stable is True
    assert ok.unstable_pools == ()


# -- hypothesis property: random saturated pools & reset/IO mixes ------------
if HAVE_HYPOTHESIS:
    from hypothesis import given, settings
    import hypothesis.strategies as st

    @settings(max_examples=20, deadline=None)
    @given(threads=st.integers(2, 6), qd=st.integers(1, 6),
           n=st.integers(20, 120),
           size_kib=st.sampled_from([4, 8, 16, 64]),
           with_resets=st.booleans(), seed=st.integers(0, 3))
    def test_property_append_pool_equivalence(threads, qd, n, size_kib,
                                              with_resets, seed):
        wl = _append_pool_workload(threads=threads, qd=qd, n=n,
                                   size=size_kib * KiB)
        if with_resets:
            wl = wl.resets(n=10, occupancy=1.0, nzones=10, zone=600,
                           io_ctx=OpType.APPEND)
        _assert_equivalent(wl, seed=seed)

    @settings(max_examples=15, deadline=None)
    @given(qd_r=st.integers(1, 8), qd_w=st.integers(1, 4),
           n=st.integers(50, 200), seed=st.integers(0, 3))
    def test_property_mixed_reset_io_equivalence(qd_r, qd_w, n, seed):
        wl = (WorkloadSpec()
              .writes(n=n, qd=qd_w, zone=0)
              .reads(n=n, qd=qd_r, zone=100, nzones=32)
              .resets(n=max(n // 10, 1), occupancy=1.0, nzones=64,
                      io_ctx=OpType.WRITE))
        _assert_equivalent(wl, seed=seed)


# -- fleet-level program -------------------------------------------------------
def test_fleet_program_matches_per_device_loop():
    wls = [_append_pool_workload(threads=4, qd=2),
           WorkloadSpec().writes(n=500, qd=4, zone=7),
           _append_pool_workload(threads=6, qd=1, n=200)]
    fleet = DeviceFleet.homogeneous(3)
    fres = fleet.run(wls, backend="vectorized", jitter=False)
    for i, wl in enumerate(wls):
        ref = ZnsDevice().run(wl, backend="vectorized", seed=i, jitter=False)
        np.testing.assert_allclose(fres[i].sim.complete, ref.sim.complete,
                                   rtol=1e-9, atol=1e-6)
        ev = ZnsDevice().run(wl, backend="event", seed=i, jitter=False)
        np.testing.assert_allclose(fres[i].sim.complete, ev.sim.complete,
                                   rtol=1e-9, atol=1e-6)


def test_fleet_program_compile_and_shapes():
    traces = [_append_pool_workload(threads=3, qd=2, n=100).build(),
              WorkloadSpec().reads(n=64, qd=2).build()]
    devs = [ZnsDevice(), ZnsDevice()]
    prog = compile_fleet_program(traces, [d.spec for d in devs],
                                 [d.lat for d in devs], cache=False)
    assert isinstance(prog, ChainProgram)
    assert prog.n_devices == 2
    assert prog.n_flat == sum(len(t) for t in traces)
    # every family block's real indices stay inside the flat range and
    # padding points at the dead slot
    for blk in prog.families:
        assert blk.gidx.max() <= prog.n_flat
        assert blk.heads.dtype == bool
    # per-device slices tile the flat vector
    covered = sorted((prog.offsets[d], len(prog.orders[d]))
                     for d in range(2))
    assert covered[0] == (0, len(traces[0]))
    assert covered[1] == (len(traces[0]), len(traces[1]))


# -- program caching -----------------------------------------------------------
def test_program_cache_roundtrip():
    clear_program_cache()
    dev = ZnsDevice()
    tr = _append_pool_workload(threads=3, qd=2, n=80).build()
    p1 = compile_program(tr, dev.spec, dev.lat)
    p2 = compile_program(tr, dev.spec, dev.lat)
    assert p1 is p2
    info = program_cache_info()
    assert info["hits"] == 1 and info["misses"] == 1
    # a different spec misses
    other = ZNSDeviceSpec(append_parallelism=4)
    p3 = compile_program(tr, other, ZnsDevice(other).lat)
    assert p3 is not p1
    assert program_cache_info()["misses"] == 2
    clear_program_cache()
    assert program_cache_info()["size"] == 0


def test_device_run_reuses_cached_program():
    clear_program_cache()
    dev = ZnsDevice()
    wl = _append_pool_workload(threads=3, qd=2, n=80)
    dev.run(wl, backend="vectorized", jitter=False)
    misses_after_first = program_cache_info()["misses"]
    dev.run(wl, backend="vectorized", jitter=False)
    dev.run(wl, backend="vectorized", jitter=False, seed=5)
    info = program_cache_info()
    assert info["misses"] == misses_after_first   # no re-lowering
    assert info["hits"] >= 2


# -- solver drivers ------------------------------------------------------------
@pytest.mark.parametrize("fixpoint", ["xla", "interpret"])
def test_kernel_fixpoint_drivers_match_numpy(fixpoint):
    dev = ZnsDevice()
    wl = (_append_pool_workload(threads=3, qd=2, n=60)
          .resets(n=8, occupancy=1.0, nzones=8, zone=600))
    tr = wl.build()
    ref = dev.run(tr, backend="vectorized", jitter=False)
    got = dev.run(tr, backend="vectorized", jitter=False, fixpoint=fixpoint)
    np.testing.assert_allclose(got.sim.complete, ref.sim.complete,
                               rtol=2e-5, atol=1e-2)   # float32 kernel
    assert got.converged


def test_solve_program_validates_inputs():
    dev = ZnsDevice()
    tr = WorkloadSpec().writes(n=32, qd=2).build()
    prog = compile_program(tr, dev.spec, dev.lat, cache=False)
    with pytest.raises(ValueError):
        solve_program(prog, np.zeros(7))
    with pytest.raises(ValueError):
        solve_program(prog, np.zeros(32), fixpoint="warp-drive")


# -- convergence diagnostics (satellite) --------------------------------------
@pytest.mark.parametrize("fixpoint", ["xla", "interpret"])
def test_kernel_fixpoint_converges_with_intra_bucket_padding(fixpoint):
    """Uneven chain lengths pad blocks with dead-slot lanes gathering
    the finite float32 NEG_INF sentinel; the moved reduction must mask
    them or every padded solve falsely reports non-convergence."""
    dev = ZnsDevice()
    wl = (WorkloadSpec()
          .appends(n=40, size=8 * KiB, qd=4, zone=0, nzones=4)
          .appends(n=64, size=8 * KiB, qd=4, zone=4, nzones=4))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        res = dev.run(wl.build(), backend="vectorized", jitter=False,
                      fixpoint=fixpoint, sweeps=8)
    assert res.converged
    assert res.sweeps_used < 8


def test_single_sweep_budget_honest_on_converged_trace():
    """A trace already at its fixpoint after one sweep must not warn or
    flag truncation when sweeps=1."""
    dev = ZnsDevice()
    # paced far apart: no queueing anywhere, nothing can move
    wl = WorkloadSpec().writes(n=8, qd=1, nzones=8, every_us=1e6)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        res = dev.run(wl, backend="vectorized", jitter=False, sweeps=1)
    assert res.converged and res.sweeps_used == 1


def test_jittered_saturated_pool_exact():
    """Jitter-aware compile: the refinement service vector is the seeded
    jittered draw, so jittered saturated pools solve exactly too.  The
    exactness claim binds to the compile seed (``svc_seeds``); solving a
    different seed reuses the chains but voids the claim."""
    dev = ZnsDevice()
    tr = _append_pool_workload().build()
    ev = dev.run(tr, backend="event", seed=3, jitter=True)
    vc = dev.run(tr, backend="vectorized", seed=3, jitter=True)
    np.testing.assert_array_equal(vc.sim.service, ev.sim.service)
    rel = np.max(np.abs(vc.sim.complete - ev.sim.complete)
                 / np.maximum(ev.sim.complete, 1.0))
    assert rel < 1e-9
    assert vc.exact is True and vc.order_stable is True


def test_sweep_exhaustion_warns_and_flags():
    dev = ZnsDevice()
    wl = (WorkloadSpec()
          .writes(n=2000, qd=4, zone=7)
          .resets(n=100, occupancy=1.0, nzones=50, io_ctx=OpType.WRITE))
    with pytest.warns(RuntimeWarning, match="sweep budget"):
        res = dev.run(wl, backend="vectorized", jitter=False, sweeps=1)
    assert not res.converged
    assert res.sweeps_used == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ok = dev.run(wl, backend="vectorized", jitter=False)
    assert ok.converged and ok.sweeps_used >= 2
    # event backend is exact by construction
    ev = dev.run(wl, backend="event", jitter=False)
    assert ev.converged and ev.sweeps_used == 0


def test_fleet_run_surfaces_convergence():
    fleet = DeviceFleet.homogeneous(2)
    wl = WorkloadSpec().writes(n=2000, qd=4, zone=7)
    with pytest.warns(RuntimeWarning, match="sweep budget"):
        fres = fleet.run(wl, policy="replicate", backend="vectorized",
                         jitter=False, sweeps=1)
    assert not fres.converged
    ok = fleet.run(wl, policy="replicate", backend="vectorized",
                   jitter=False)
    assert ok.converged


# -- auto-threshold knob (satellite) ------------------------------------------
def test_auto_threshold_knob_regression():
    wl = WorkloadSpec().writes(n=256, size=4 * KiB, qd=2)
    assert ZnsDevice().run(wl, jitter=False).backend == "event"
    dev = ZnsDevice(auto_threshold=128)
    assert dev.auto_threshold == 128
    assert dev.run(wl, jitter=False).backend == "vectorized"
    assert ZnsDevice(auto_threshold=10**9).run(
        wl, jitter=False).backend == "event"
    # default constant still documents the session default
    assert ZnsDevice().auto_threshold == AUTO_VECTORIZED_MIN
    # fleets take the same knob
    fleet = DeviceFleet.homogeneous(2, ZNSDeviceSpec())
    fleet_low = DeviceFleet([ZNSDeviceSpec()] * 2, auto_threshold=128)
    assert fleet.run(wl, policy="replicate",
                     jitter=False).backend == "event"
    assert fleet_low.run(wl, policy="replicate",
                         jitter=False).backend == "vectorized"


# -- host scenarios stay exact through the compiled path ----------------------
def test_host_scenarios_exact_on_compiled_path():
    from repro.host import build_scenario
    from repro.host.scenarios import HOST_SCENARIO_SPEC
    b = build_scenario("lsm", policy="greedy-open")
    _assert_equivalent(b.workload, spec=HOST_SCENARIO_SPEC)
    vol_prog = b.volume.compile_program()
    assert vol_prog.exact


def test_default_refine_budget_documented():
    assert DEFAULT_REFINE >= 1


# -- layouts ------------------------------------------------------------------
def test_cols_layout_matches_rows_and_event(monkeypatch):
    """Force the position-loop (transposed ``cols``) layout and check it
    solves identically to the doubling-scan ``rows`` layout and the
    event engine — large fleets pick it automatically via the cost
    model; tests pin it explicitly."""
    from repro.core import chain_program as cp
    dev = ZnsDevice()
    wl = (_append_pool_workload(threads=6, qd=2, n=120)
          .writes(n=300, qd=4, zone=100))
    tr = wl.build()
    default = compile_program(tr, dev.spec, dev.lat, cache=False)
    monkeypatch.setattr(cp, "POSLOOP_MIN_CHAINS", 1)
    monkeypatch.setattr(cp, "POSLOOP_COST_CUTOVER", 0.0)
    forced = compile_program(tr, dev.spec, dev.lat, cache=False)
    assert {b.layout for b in forced.families} == {"cols"}
    assert any(b.layout == "rows" for b in default.families)
    c1, _, cv1 = solve_program(default, default.svc0_flat, sweeps=16)
    c2, _, cv2 = solve_program(forced, forced.svc0_flat, sweeps=16)
    assert cv1 and cv2
    np.testing.assert_allclose(c1, c2, rtol=1e-9, atol=1e-6)
    ev = simulate(tr, dev.spec, dev.lat, seed=0, jitter=False)
    np.testing.assert_allclose(c2[forced.invs[0]], ev.complete,
                               rtol=1e-9, atol=1e-6)
