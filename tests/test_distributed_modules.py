"""shard_map modules: flash-decode, EP MoE, compressed collectives —
correctness vs single-device oracles (subprocess: multi-device pool)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_flash_decode_matches_dense():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.distributed.flash_decode import flash_decode
        from repro.kernels import ref
        rng = np.random.default_rng(0)
        B, K, rep, S, D = 4, 2, 3, 64, 32
        q = jnp.array(rng.standard_normal((B, K, rep, D)), jnp.float32)
        ck = jnp.array(rng.standard_normal((B, K, S, D)), jnp.float32)
        cv = jnp.array(rng.standard_normal((B, K, S, D)), jnp.float32)
        pos = jnp.int32(37)
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        with mesh:
            out = flash_decode(mesh, q, ck, cv, pos)
        # oracle: dense grouped attention with kv-length mask
        import jax.nn as jnn
        logits = jnp.einsum("bkrd,bksd->bkrs", q, ck) / np.sqrt(D)
        valid = jnp.arange(S) <= pos
        logits = jnp.where(valid[None,None,None,:], logits, -1e30)
        w = jnn.softmax(logits, -1)
        want = jnp.einsum("bkrs,bksd->bkrd", w, cv)
        err = float(jnp.max(jnp.abs(out - want)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_ep_moe_matches_gspmd_no_drop():
    out = _run("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro import models as M
        from repro.distributed import ctx as dctx
        from repro.distributed import sharding as sh
        base = get_smoke_config("qwen2-moe-a2.7b")
        cfg_ep = dataclasses.replace(base, moe_impl="ep", moe_expert_pad=2,
                                     moe_capacity_factor=8.0)
        cfg_gs = dataclasses.replace(base, moe_expert_pad=2,
                                     moe_capacity_factor=8.0)
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg_gs, key)
        toks = jax.random.randint(key, (4, 32), 0, base.vocab_size)
        l0, _ = M.forward(cfg_gs, params, toks)
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        rules = sh.make_rules(data_axes=("data",))
        with mesh, dctx.axis_rules(mesh, rules):
            l1, _ = jax.jit(lambda p, t: M.forward(cfg_ep, p, t))(params, toks)
        err = float(jnp.max(jnp.abs(l0 - l1)))
        assert err < 1e-3, err
        print("OK", err)
    """)
    assert "OK" in out


def test_ef_compressed_psum_semantics():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.distributed.collectives import (
            ef_compressed_psum, compressed_psum_reference, init_error_state)
        rng = np.random.default_rng(0)
        mesh = Mesh(np.array(jax.devices()[:4]).reshape(4,), ("pod",))
        per_pod = [jnp.array(rng.standard_normal((8, 16)) * (i + 1),
                             jnp.float32) for i in range(4)]
        stacked = {"g": jnp.stack(per_pod)}
        err0 = {"g": jnp.zeros((4, 8, 16), jnp.float32)}
        for method in ("bf16", "int8"):
            with mesh:
                out, errs = ef_compressed_psum(mesh, stacked, err0,
                                               method=method)
            want = compressed_psum_reference(per_pod, method)
            d = float(jnp.max(jnp.abs(out["g"] - want)))
            # bf16 wire: reduction-order rounding differs from the oracle
            tol = 2e-2 if method == "bf16" else 1e-4
            assert d < tol, (method, d)
            # error feedback: residual equals the true quantization error
            true = sum(per_pod) / 4
            resid = float(jnp.max(jnp.abs(out["g"] + 0 - true)))
            carried = float(jnp.max(jnp.abs(errs["g"])))
            assert carried > 0.0   # something is fed back
        print("OK")
    """)
    assert "OK" in out


def test_ef_accumulated_error_is_bounded():
    """Over many steps, EF keeps the accumulated update near the exact sum."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.distributed.collectives import ef_compressed_psum
        rng = np.random.default_rng(1)
        mesh = Mesh(np.array(jax.devices()[:4]).reshape(4,), ("pod",))
        err = {"g": jnp.zeros((4, 16), jnp.float32)}
        acc_comp = jnp.zeros(16)
        acc_true = jnp.zeros(16)
        for step in range(30):
            per_pod = jnp.array(rng.standard_normal((4, 16)) * 0.01,
                                jnp.float32)
            with mesh:
                o, err = ef_compressed_psum(mesh, {"g": per_pod}, err,
                                            method="int8")
            acc_comp = acc_comp + o["g"]
            acc_true = acc_true + jnp.mean(per_pod, 0)
        drift = float(jnp.max(jnp.abs(acc_comp - acc_true)))
        rel = drift / float(jnp.max(jnp.abs(acc_true)))
        assert rel < 0.2, rel
        print("OK", rel)
    """)
    assert "OK" in out
