"""Runtime control-plane policies: lease-based failure detection,
straggler mitigation, restart budgets (runtime/failures.py) and
deterministic elastic resharding (runtime/elastic.py)."""
import pytest

from repro.runtime.elastic import (
    largest_mesh, make_reshard_plan, validate_plan,
)
from repro.runtime.failures import (
    FailureDetector, HostState, RestartBudget, StragglerPolicy,
)


# ---------------------------------------------------------------------------
# FailureDetector
# ---------------------------------------------------------------------------
def test_lease_transitions_healthy_suspect_dead():
    det = FailureDetector(3, lease_s=10.0)
    for h in range(3):
        det.heartbeat(h, now=0.0)
    assert det.tick(5.0) == {}                       # within lease
    changes = det.tick(15.0)                         # one lease missed
    assert changes == {0: HostState.SUSPECT, 1: HostState.SUSPECT,
                       2: HostState.SUSPECT}
    det.heartbeat(1, now=16.0)                       # host 1 recovers
    changes = det.tick(25.0)                         # two leases missed
    assert changes[0] is HostState.DEAD and changes[2] is HostState.DEAD
    assert 1 not in changes                          # stayed healthy
    assert det.healthy_hosts() == [1]


def test_dead_host_rejoins_with_new_incarnation():
    det = FailureDetector(2, lease_s=1.0)
    det.heartbeat(0, now=0.0)
    det.heartbeat(1, now=0.0)
    det.tick(10.0)
    assert det.hosts[0].state is HostState.DEAD
    assert det.hosts[0].incarnation == 0
    det.heartbeat(0, now=11.0)
    assert det.hosts[0].state is HostState.HEALTHY
    assert det.hosts[0].incarnation == 1             # fenced rejoin
    det.heartbeat(0, now=12.0)
    assert det.hosts[0].incarnation == 1             # no bump while alive


def test_suspect_hosts_still_participate():
    det = FailureDetector(2, lease_s=5.0)
    det.heartbeat(0, now=0.0)
    det.heartbeat(1, now=0.0)
    det.tick(7.0)
    assert det.hosts[0].state is HostState.SUSPECT
    assert det.healthy_hosts() == [0, 1]             # SUSPECT != DEAD


# ---------------------------------------------------------------------------
# StragglerPolicy
# ---------------------------------------------------------------------------
def test_straggler_deadline_needs_history():
    pol = StragglerPolicy(factor=1.5, window=8)
    for d in (10.0, 11.0, 9.0):
        pol.observe(d)
    assert pol.deadline() is None                    # < 4 observations
    assert pol.mitigate({0: 100.0}) == {}
    pol.observe(10.0)
    assert pol.deadline() == pytest.approx(15.0)     # 1.5 x median


def test_straggler_mitigation_assigns_next_host_backup():
    pol = StragglerPolicy(factor=1.5, window=8)
    for d in (10.0,) * 8:
        pol.observe(d)
    plans = pol.mitigate({0: 9.0, 1: 40.0, 2: 11.0, 3: 16.0})
    assert plans == {1: 2, 3: 0}                     # wraps around
    assert 0 not in plans and 2 not in plans


def test_straggler_window_bounds_history():
    pol = StragglerPolicy(factor=2.0, window=4)
    for d in (100.0,) * 4:
        pol.observe(d)
    for d in (10.0,) * 4:                            # window slides off 100s
        pol.observe(d)
    assert pol.deadline() == pytest.approx(20.0)


# ---------------------------------------------------------------------------
# RestartBudget
# ---------------------------------------------------------------------------
def test_restart_budget_caps_storms():
    budget = RestartBudget(max_restarts=3, window_s=100.0)
    assert all(budget.allow(t) for t in (0.0, 1.0, 2.0))
    assert not budget.allow(3.0)                     # 4th inside window
    assert not budget.allow(99.0)
    assert budget.allow(101.5)                       # window slid


def test_restart_budget_denied_attempts_not_counted():
    budget = RestartBudget(max_restarts=1, window_s=10.0)
    assert budget.allow(0.0)
    for t in (1.0, 2.0, 3.0):
        assert not budget.allow(t)                   # denials don't extend
    assert budget.allow(10.5)


# ---------------------------------------------------------------------------
# Elastic resharding
# ---------------------------------------------------------------------------
def test_largest_mesh_keeps_model_parallel_fixed():
    assert largest_mesh(64, model_parallel=16) == (4, 16)
    assert largest_mesh(66, model_parallel=16) == (4, 16)   # rounds down
    with pytest.raises(ValueError, match="fewer than"):
        largest_mesh(15, model_parallel=16)


def test_reshard_plan_covers_all_shards_once():
    plan = make_reshard_plan(range(8), (0, 1, 2, 5, 6, 7),
                             model_parallel=4, chips_per_host=4)
    validate_plan(plan)                              # no assertion raised
    assert plan.new_hosts == (0, 1, 2, 5, 6, 7)
    owned = sorted(s for lst in plan.shard_ownership.values() for s in lst)
    assert owned == list(range(8))                   # every old shard once
    assert plan.mesh_shape == (6, 4)


def test_reshard_plan_is_deterministic_and_coordinator_free():
    a = make_reshard_plan((3, 1, 0, 2), (0, 2, 3), model_parallel=4)
    b = make_reshard_plan((0, 1, 2, 3), (3, 2, 0), model_parallel=4)
    assert a == b                                    # order-insensitive


def test_reshard_plan_rejects_empty_survivor_set():
    with pytest.raises(ValueError, match="empty healthy host set"):
        make_reshard_plan((0, 1), (), model_parallel=4)


def test_validate_plan_catches_corruption():
    plan = make_reshard_plan(range(4), range(4), model_parallel=4)
    bad = plan.shard_ownership.copy()
    bad[0] = bad[0] + [0]                            # duplicate shard 0
    import dataclasses
    broken = dataclasses.replace(plan, shard_ownership=bad)
    with pytest.raises(AssertionError, match="every old shard once"):
        validate_plan(broken)
