"""Golden-trace differential regression for the host scenarios.

Each fixture under ``tests/golden/`` pins one scenario's compiled
workload (a digest of the lowered trace) and its per-op completion times
on the ``event`` backend (jitter off).  The suite asserts

* the model still produces byte-identical workloads and float-equal
  completion times (catching accidental semantic drift in the host
  layer, the workload builder, or either engine), and
* ``event`` vs ``vectorized`` equivalence for **every** scenario x
  placement-policy combination (freshly computed, not fixture-bound).

Regenerate after an *intentional* model change with::

    pytest tests/test_host_golden.py --regen-golden
"""
import hashlib
import json
import pathlib

import numpy as np
import pytest

from repro.core import ZnsDevice
from repro.host import available_placement_policies, build_scenario
from repro.host.scenarios import HOST_SCENARIO_SPEC

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"

#: (scenario, policy, seed, scale) pinned by a fixture each.
GOLDEN_CASES = (
    ("lsm", "greedy-open", 0, 0.5),
    ("circular-log", "greedy-open", 0, 0.5),
    ("cache", "greedy-open", 0, 0.5),
)

ALL_COMBOS = tuple(
    (scen, pol)
    for scen in ("lsm", "circular-log", "cache")
    for pol in ("greedy-open", "striped", "lifetime-binned"))


def _trace_digest(trace) -> str:
    h = hashlib.sha256()
    for field in ("op", "zone", "size", "issue", "thread", "qd",
                  "occupancy", "was_finished", "io_ctx"):
        h.update(np.ascontiguousarray(getattr(trace, field)).tobytes())
    return h.hexdigest()


def _compute(scenario: str, policy: str, seed: int, scale: float) -> dict:
    build = build_scenario(scenario, policy=policy, seed=seed, scale=scale)
    trace = build.workload.build()
    dev = ZnsDevice(HOST_SCENARIO_SPEC)
    res = dev.run(trace, backend="event", seed=seed, jitter=False)
    return {
        "scenario": scenario, "policy": policy, "seed": seed, "scale": scale,
        "n_requests": len(trace),
        "workload_sha256": _trace_digest(trace),
        "complete_us": [float(c) for c in res.sim.complete],
    }


def _fixture_path(scenario: str, policy: str) -> pathlib.Path:
    return GOLDEN_DIR / f"{scenario}__{policy}.json"


@pytest.mark.parametrize("scenario,policy,seed,scale", GOLDEN_CASES,
                         ids=lambda v: str(v))
def test_golden_trace_regression(request, scenario, policy, seed, scale):
    path = _fixture_path(scenario, policy)
    got = _compute(scenario, policy, seed, scale)
    if request.config.getoption("--regen-golden"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        with open(path, "w") as f:
            json.dump(got, f, indent=0)
        pytest.skip(f"regenerated {path.name}")
    assert path.exists(), \
        f"missing golden fixture {path}; run pytest --regen-golden"
    with open(path) as f:
        want = json.load(f)
    assert got["n_requests"] == want["n_requests"], \
        "compiled workload changed size — intentional? --regen-golden"
    assert got["workload_sha256"] == want["workload_sha256"], \
        "compiled workload changed content — intentional? --regen-golden"
    np.testing.assert_allclose(
        np.asarray(got["complete_us"]), np.asarray(want["complete_us"]),
        rtol=1e-9, atol=1e-6,
        err_msg="event-backend completion times drifted from the golden "
                "trace — intentional? --regen-golden")


@pytest.mark.parametrize("scenario,policy", ALL_COMBOS,
                         ids=lambda v: str(v))
def test_event_vs_vectorized_equivalence(scenario, policy):
    """Differential check: both backends produce float-equal completion
    times for every host scenario under every placement policy."""
    build = build_scenario(scenario, policy=policy, seed=0, scale=0.5)
    trace = build.workload.build()
    dev = ZnsDevice(HOST_SCENARIO_SPEC)
    ev = dev.run(trace, backend="event", jitter=False)
    vec = dev.run(trace, backend="vectorized", jitter=False)
    np.testing.assert_allclose(vec.sim.complete, ev.sim.complete,
                               rtol=1e-9, atol=1e-6)
    np.testing.assert_allclose(vec.sim.start, ev.sim.start,
                               rtol=1e-9, atol=1e-6)
    np.testing.assert_array_equal(vec.sim.service, ev.sim.service)


def test_golden_fixtures_cover_every_scenario():
    from repro.host import available_scenarios
    pinned = {c[0] for c in GOLDEN_CASES}
    assert pinned == set(available_scenarios()), \
        "every registered scenario needs a golden fixture"
