import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def pytest_addoption(parser):
    parser.addoption(
        "--regen-golden", action="store_true", default=False,
        help="rewrite tests/golden/*.json fixtures from the current model "
             "instead of asserting against them")
