import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
