"""Sharding-rule resolution, sanitization, and spec/shape divisibility
across all architectures (no multi-device needed: pure spec logic)."""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec as PS

from repro import models as M
from repro.configs import ARCH_IDS, get_config
from repro.distributed import sharding as sh
from repro.launch import specs as SP
from repro.models.config import SHAPES_BY_NAME, shapes_for
from repro.train.step import state_logical_axes, state_spec


def _fake_mesh(shape, axes):
    # AbstractMesh builds without devices — enough for spec resolution.
    # Signature changed across jax versions: older takes a shape_tuple of
    # (name, size) pairs, newer takes (axis_sizes, axis_names).
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return AbstractMesh(shape, axes)


MESHES = [
    _fake_mesh((16, 16), ("data", "model")),
    _fake_mesh((2, 16, 16), ("pod", "data", "model")),
]


def test_rules_no_duplicate_mesh_axes_per_spec():
    mesh = MESHES[1]
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        specs = sh.tree_specs(M.logical_axes(cfg), mesh=mesh)
        for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, PS)):
            flat = []
            for entry in spec:
                if entry is None:
                    continue
                flat.extend([entry] if isinstance(entry, str) else list(entry))
            assert len(flat) == len(set(flat)), (arch, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
def test_sanitized_state_specs_divide_shapes(arch, mesh):
    cfg = get_config(arch, kernel_impl="xla")
    shapes = state_spec(cfg)
    axes = state_logical_axes(cfg)
    specs = sh.tree_specs(axes, mesh=mesh)
    specs = sh.sanitize(shapes, specs, mesh)
    sh.validate_specs(shapes, specs, mesh)   # must not raise


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_sanitized_input_specs_divide_shapes(arch):
    mesh = MESHES[1]
    cfg = get_config(arch, kernel_impl="xla")
    for shape in shapes_for(cfg):
        ins = SP.input_specs(cfg, shape)
        if shape.kind == "decode":
            axes = SP.decode_logical_axes(cfg)
        else:
            axes = SP.batch_logical_axes(cfg)
        specs = sh.tree_specs(axes, mesh=mesh)
        specs = sh.sanitize(ins, specs, mesh)
        sh.validate_specs(ins, specs, mesh)


def test_sanitize_drops_indivisible_axes():
    mesh = MESHES[0]
    spec = sh.sanitize(
        [jax.ShapeDtypeStruct((8, 33), np.float32)],
        [PS("data", "model")], mesh)[0]
    # 8 % 16 != 0 and 33 % 16 != 0 -> both dropped
    assert spec == PS()


def test_fsdp_rules_shard_embed_over_pod_and_data():
    rules = sh.make_rules()
    spec = sh.spec_from_axes(("embed", "mlp"), rules, MESHES[1])
    assert spec == PS(("pod", "data"), "model")


def test_no_rule_raises_keyerror():
    with pytest.raises(KeyError):
        sh.spec_from_axes(("nonexistent_axis",), sh.DEFAULT_RULES, MESHES[0])


def test_optimized_presets_resolve():
    from repro.configs import get_optimized_config, step_settings
    c = get_optimized_config("qwen2-moe-a2.7b")
    assert c.moe_impl == "ep" and c.moe_expert_pad == 4
    assert (c.moe_num_experts + c.moe_expert_pad) % 16 == 0
    a = get_optimized_config("arctic-480b")
    assert a.moe_impl == "ep" and a.moe_num_experts % 16 == 0
    assert step_settings("llama3-405b")["microbatches"] == 16
    # non-MoE archs pass through unchanged
    t = get_optimized_config("tinyllama-1.1b")
    assert t.moe_impl == "gspmd"
