"""Event-engine invariants (hypothesis, via the shared ``strategies``
module) + steady-state model sanity."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (
    KiB, OpType, Stack, ThroughputModel, simulate,
)
from repro.core.engine import zone_sequential_completions
from strategies import io_trace_args, random_io_trace


@given(io_trace_args())
@settings(max_examples=25, deadline=None)
def test_engine_conservation_and_ordering(args):
    n, qd, seed = args
    tr = random_io_trace(n, qd, seed)
    res = simulate(tr, seed=seed)
    # completion after start, start after issue is NOT guaranteed (closed
    # loop gates on ring), but start is never negative and svc > 0
    assert (res.complete >= res.start).all()
    assert (res.service > 0).all()
    assert (res.start >= 0).all()
    # per-zone write serialization: write intervals in a zone don't overlap
    for z in range(10):
        m = (tr.zone == z) & (tr.op == OpType.WRITE)
        if m.sum() < 2:
            continue
        s, c = res.start[m], res.complete[m]
        order = np.argsort(s)
        assert (s[order][1:] >= c[order][:-1] - 1e-6).all()


@given(st.integers(2, 400), st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_zone_sequential_completions_properties(n, seed):
    rng = np.random.default_rng(seed)
    issue = np.sort(rng.uniform(0, 1e4, n))
    svc = rng.uniform(0.5, 40, n)
    seg = rng.uniform(size=n) < 0.08
    seg[0] = True
    out = zone_sequential_completions(issue, svc, seg, backend="numpy")
    # each completion >= issue + svc; within a segment, strictly increasing
    assert (out >= issue + svc - 1e-6).all()
    cur_seg_start = 0
    for i in range(1, n):
        if seg[i]:
            cur_seg_start = i
            continue
        assert out[i] >= out[i - 1] + svc[i] - 1e-6


def test_steady_state_monotone_in_concurrency():
    tm = ThroughputModel()
    last = 0.0
    for qd in (1, 2, 4, 8, 16, 32):
        iops = tm.steady_state(OpType.READ, 4 * KiB, qd=qd).iops
        assert iops >= last - 1e-6
        last = iops


def test_steady_state_rejects_spdk_multi_write_per_zone():
    tm = ThroughputModel()
    import pytest
    with pytest.raises(ValueError):
        tm.steady_state(OpType.WRITE, 4 * KiB, qd=4, stack=Stack.SPDK)


def test_bandwidth_never_exceeds_device_cap():
    tm = ThroughputModel()
    for op in (OpType.WRITE, OpType.APPEND):
        for size_k in (4, 16, 64, 256):
            for qd in (1, 4, 16):
                for zones in (1, 4):
                    if op == OpType.WRITE and qd > 1:
                        continue
                    r = tm.steady_state(op, size_k * KiB, qd=qd, zones=zones)
                    assert r.bandwidth_bytes <= tm.spec.peak_write_bw_bytes * 1.001
