"""Data pipeline determinism/resume, optimizer math, failure policies,
elastic resharding."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import DataConfig, TokenPipeline
from repro.optim import (
    AdamWConfig, adamw_update, clip_by_global_norm, init_opt_state,
    schedule_lr,
)
from repro.runtime import (
    FailureDetector, HostState, RestartBudget, StragglerPolicy,
    make_reshard_plan, validate_plan,
)


# -- data ---------------------------------------------------------------------
def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
    p1 = TokenPipeline(cfg)
    batches = [next(p1) for _ in range(3)]
    p2 = TokenPipeline(cfg)
    p2.load_state_dict({"step": 2})
    np.testing.assert_array_equal(next(p2)["tokens"], batches[2]["tokens"])


def test_pipeline_sharding_partitions_global_batch():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=8)
    shards = [TokenPipeline(cfg, shard=s, num_shards=4).batch_at(0)["tokens"]
              for s in range(4)]
    assert all(s.shape == (2, 8) for s in shards)
    # distinct shards
    assert not np.array_equal(shards[0], shards[1])


def test_pipeline_elastic_reshard_preserves_step():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=12)
    p = TokenPipeline(cfg, shard=0, num_shards=4)
    for _ in range(5):
        next(p)
    q = p.reshard(shard=1, num_shards=3)
    assert q.state.step == 5
    assert q.batch_at(5)["tokens"].shape == (4, 8)


def test_token_distribution_is_zipfish():
    cfg = DataConfig(vocab_size=1000, seq_len=256, global_batch=16)
    toks = TokenPipeline(cfg).batch_at(0)["tokens"].ravel()
    counts = np.bincount(toks, minlength=1000)
    top = counts.max() / len(toks)
    assert top > 5.0 / 1000       # head much heavier than uniform


# -- optimizer -------------------------------------------------------------------
def test_adamw_matches_manual_reference():
    cfg = AdamWConfig(lr=0.1, beta1=0.9, beta2=0.99, eps=1e-8,
                      weight_decay=0.0, clip_norm=1e9, warmup_steps=0,
                      schedule="constant")
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st = init_opt_state(p)
    new_p, st2, _ = adamw_update(cfg, p, g, st, jnp.int32(0))
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expect = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    assert float(new_p["w"][0]) == pytest.approx(expect, rel=1e-5)


def test_weight_decay_decoupled():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=1e9,
                      warmup_steps=0, schedule="constant")
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.0])}
    new_p, _, _ = adamw_update(cfg, p, g, init_opt_state(p), jnp.int32(0))
    assert float(new_p["w"][0]) == pytest.approx(2.0 - 0.1 * 0.5 * 2.0)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    total = np.sqrt(float(clipped["a"][0]) ** 2 + float(clipped["b"][0]) ** 2)
    assert total == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    assert float(schedule_lr(cfg, jnp.int32(0))) == pytest.approx(0.0)
    assert float(schedule_lr(cfg, jnp.int32(10))) == pytest.approx(1.0)
    end = float(schedule_lr(cfg, jnp.int32(110)))
    assert end == pytest.approx(0.1, rel=1e-3)


# -- failure detection / straggler / restart budget ------------------------------
def test_failure_detector_transitions():
    fd = FailureDetector(3, lease_s=10)
    for h in range(3):
        fd.heartbeat(h, 0.0)
    assert fd.tick(5.0) == {}
    ch = fd.tick(15.0)
    assert all(s is HostState.SUSPECT for s in ch.values())
    ch = fd.tick(25.0)
    assert all(s is HostState.DEAD for s in ch.values())
    fd.heartbeat(1, 26.0)
    assert fd.hosts[1].state is HostState.HEALTHY
    assert fd.hosts[1].incarnation == 1
    assert fd.healthy_hosts() == [1]


def test_straggler_policy_backups():
    sp = StragglerPolicy(factor=1.5)
    for d in (1.0, 1.1, 0.9, 1.0, 1.05):
        sp.observe(d)
    plan = sp.mitigate({0: 1.0, 1: 5.0, 2: 1.1})
    assert plan == {1: 2}


def test_restart_budget():
    rb = RestartBudget(max_restarts=2, window_s=100)
    assert rb.allow(0.0) and rb.allow(1.0)
    assert not rb.allow(2.0)
    assert rb.allow(200.0)


# -- elastic ----------------------------------------------------------------------
def test_reshard_plan_valid_and_deterministic():
    old = list(range(8))
    new = [0, 1, 2, 4, 5, 6, 7]       # host 3 died
    p1 = make_reshard_plan(old, new, model_parallel=4, chips_per_host=4)
    p2 = make_reshard_plan(old, new, model_parallel=4, chips_per_host=4)
    assert p1 == p2
    validate_plan(p1)
    assert p1.mesh_shape == (7, 4)
    ranks = [p1.data_shards[h][0] for h in sorted(p1.data_shards)]
    assert sorted(ranks) == list(range(7))


def test_reshard_rejects_too_few_chips():
    with pytest.raises(ValueError):
        make_reshard_plan([0, 1], [0], model_parallel=16, chips_per_host=4)
