"""Emit the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
reports/ JSONs.

  PYTHONPATH=src python scripts/make_experiments_tables.py > reports/tables.md
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import glob
import json

from repro.launch.roofline import load_cells, roofline_row

ARCH_ORDER = [
    "tinyllama-1.1b", "qwen3-4b", "qwen3-8b", "llama3-405b", "arctic-480b",
    "qwen2-moe-a2.7b", "mamba2-370m", "internvl2-26b", "musicgen-large",
    "recurrentgemma-9b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    cells = load_cells(["reports/dryrun", "reports/dryrun_fitfix"])

    print("### §Dry-run — all (arch x shape x mesh) cells\n")
    print("| arch | shape | single-pod 16x16 | multi-pod 2x16x16 | "
          "GiB/dev (single/multi) | collectives (single, per-chip wire GB) |")
    print("|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            s = cells.get((arch, shape, "single"))
            m = cells.get((arch, shape, "multi"))
            if s is None:
                continue
            if s.get("status") == "skipped":
                print(f"| {arch} | {shape} | skip (full attention) | skip | — | — |")
                continue
            def memgib(r):
                mm = r["full"]["memory"]
                return (mm["argument_bytes"] + mm["temp_bytes"]) / 2**30
            cw = s["full"]["collectives"]["total_wire_bytes"] / 1e9
            counts = s["full"]["collectives"]["count"]
            cstr = "+".join(f"{k.split('-')[1] if '-' in k else k}:{v}"
                            for k, v in counts.items() if v)
            print(f"| {arch} | {shape} | {s['status']} | "
                  f"{m['status'] if m else '—'} | "
                  f"{memgib(s):.1f} / {memgib(m):.1f} | {cw:.1f} ({cstr}) |")

    print("\n### §Roofline — single-pod (256 chips), per-chip terms\n")
    print("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant |"
          " useful-FLOP ratio | roofline frac | note |")
    print("|---|---|---|---|---|---|---|---|---|" .replace("|---|---|---|---|---|---|---|---|---|", "|---|---|---|---|---|---|---|---|"))
    notes = {
        ("arctic-480b", "prefill_32k"): "MoE dispatch gathers; fix=EP a2a (§Perf C)",
        ("arctic-480b", "train_4k"): "same; EP a2a (§Perf C)",
        ("qwen2-moe-a2.7b", "train_4k"): "worst coll/comp ratio; fix=EP a2a (§Perf A)",
        ("qwen2-moe-a2.7b", "prefill_32k"): "EP a2a applies",
        ("llama3-405b", "train_4k"): "TP activation ARs dominate wire (§Perf B)",
        ("llama3-405b", "prefill_32k"): "TP ARs at 32k seq; ring-attention would cut",
        ("llama3-405b", "decode_32k"): "KV-cache streaming bound",
        ("mamba2-370m", "train_4k"): "small model: HBM-bound; grow per-chip batch",
        ("mamba2-370m", "long_500k"): "O(1) state; chip underutilized at B=1",
        ("recurrentgemma-9b", "long_500k"): "window cache tiny; B=1 underutilizes",
        ("musicgen-large", "decode_32k"): "MHA kv=32: cache reads dominate; GQA or wider batch",
        ("internvl2-26b", "train_4k"): "TP ARs; SP-via-shard_map next",
    }
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, "single"))
            if not r or r.get("status") != "ok":
                continue
            row = roofline_row(r)
            if row is None:
                continue
            nt = notes.get((arch, shape), "")
            print(f"| {arch} | {shape} | {row['t_compute_s']:.3g} | "
                  f"{row['t_memory_s']:.3g} | {row['t_collective_s']:.3g} | "
                  f"{row['dominant']} | {row['useful_flop_ratio']:.2f} | "
                  f"{row['roofline_fraction']:.3f} | {nt} |")


if __name__ == "__main__":
    main()
