import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Print the largest collective instructions for one dry-run cell
(small unrolled depth), sorted by result bytes — the perf-loop's
'profiler'.

  PYTHONPATH=src python scripts/inspect_collectives.py --arch llama3-405b \
      --shape train_4k [--depth 2] [--top 25] [...dryrun flags]
"""
import argparse
import dataclasses
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.launch.dryrun import lower_cell, _rules_for
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES_BY_NAME
from repro.utils.hlo import _INSTR_RE, _shape_bytes
from repro.distributed.ctx import axis_rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="")
    ap.add_argument("--moe-impl", default="", dest="moe_impl")
    ap.add_argument("--moe-pad", type=int, default=0, dest="moe_pad")
    ap.add_argument("--remat-block", type=int, default=0, dest="remat_block")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-seqshard", action="store_true")
    ap.add_argument("--no-ep", action="store_true")
    args = ap.parse_args()

    overrides = {"kernel_impl": "xla", "scan_layers": False}
    if args.remat:
        overrides["remat"] = args.remat
    if args.moe_impl:
        overrides["moe_impl"] = args.moe_impl
    if args.moe_pad:
        overrides["moe_expert_pad"] = args.moe_pad
    cfg = get_config(args.arch, **overrides)
    cfg = dataclasses.replace(cfg, num_layers=args.depth)
    shape = SHAPES_BY_NAME[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    rules = _rules_for(mesh, args)
    with mesh, axis_rules(mesh, rules):
        compiled, _ = lower_cell(cfg, shape, mesh, args)
    rows = []
    for line in compiled.as_text().splitlines():
        if "-done" in line:
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        result_text, kind, _ = m.groups()
        rows.append((_shape_bytes(result_text), kind,
                     line.strip()[:170]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"# {len(rows)} collectives, total result bytes/chip "
          f"{total/2**30:.3f} GiB (depth={args.depth})")
    for nbytes, kind, line in rows[:args.top]:
        print(f"{nbytes/2**20:10.1f} MiB  {kind:18s} {line}")


if __name__ == "__main__":
    main()
