"""Checkpoint-engine hillclimb: hypothesis -> change -> measure -> validate.

Scenario: a 405B-class TrainState (bf16 params + f32 moments ~ 4 TB)
checkpointed from 512 hosts, 7.9 GiB/host, each host owning one ZN540.
The metric is the end-to-end checkpoint *cycle*: payload write + commit
+ zone reclaim, with the fleet wall time = straggler (p-max over hosts).

Host-time jitter: hosts see +/- lognormal service variation (fio-style
run-to-run sigma ~6%, paper Tab. II methodology: 3 repeats) plus a 2%
chance of a 2-4x degraded device (aging / thermal).

  PYTHONPATH=src python scripts/zns_hillclimb.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import KiB, MiB, GiB, OpType
from repro.runtime.zns_store import ZnsHostDevice

N_HOSTS = 512
SHARD = int(7.9 * GiB)
RNG = np.random.default_rng(0)


def fleet_wall(per_host_s: float, *, redundancy: bool, straggler_factor=1.5,
               n=N_HOSTS, seed=0):
    rng = np.random.default_rng(seed)
    jitter = np.exp(0.06 * rng.standard_normal(n))
    degraded = rng.uniform(size=n) < 0.02
    times = per_host_s * jitter * np.where(degraded,
                                           rng.uniform(2, 4, n), 1.0)
    if redundancy:
        med = np.median(times)
        # backup write kicks in at deadline; backup host re-writes the
        # shard at full speed -> capped at deadline + median
        dl = med * straggler_factor
        times = np.where(times > dl, dl + med, times)
    return float(np.max(times)), float(np.median(times))


def cycle(name, *, stripe, qd, zones, redundancy, concurrent_gc,
          manifest_op=OpType.WRITE):
    dev = ZnsHostDevice(0, stripe_bytes=stripe, append_qd=qd,
                        concurrent_zones=zones)
    zns = dev.device            # the ZnsDevice session handle
    write_s, n_req = dev.simulate_payload_write(SHARD)
    man_us = float(zns.io_latency_us(manifest_op, 4 * KiB))
    # reclaim: the zones of the previous checkpoint of equal size
    n_zones = int(np.ceil(SHARD / zns.spec.zone_cap_bytes))
    occ = 1.0
    reset_us = float(np.asarray(zns.reset_latency_us(occ)).mean()) * n_zones
    if concurrent_gc:
        reset_us *= zns.lat.reset_inflation([OpType.APPEND])
        host_s = max(write_s, reset_us / 1e6) + man_us / 1e6
    else:
        host_s = write_s + reset_us / 1e6 + man_us / 1e6
    wall, med = fleet_wall(host_s, redundancy=redundancy)
    bw = SHARD / write_s / MiB
    print(f"{name:52s} host={host_s:6.2f}s wall_p100={wall:6.2f}s "
          f"med={med:6.2f}s bw={bw:5.0f}MiB/s req={n_req}")
    return wall


def main():
    print(f"fleet: {N_HOSTS} hosts x {SHARD/GiB:.1f} GiB shards "
          f"(405B-class state)\n")
    rows = {}
    rows["naive: 4KiB appends QD1, serial GC, no redundancy"] = cycle(
        "naive: 4KiB appends QD1, serial GC, no redundancy",
        stripe=4 * KiB, qd=1, zones=1, redundancy=False, concurrent_gc=False)
    rows["paper R1-R5: 1MiB QD4, concurrent GC"] = cycle(
        "paper R1-R5: 1MiB QD4, concurrent GC",
        stripe=1 * MiB, qd=4, zones=1, redundancy=False, concurrent_gc=True)
    rows["+ straggler mitigation (backup writes)"] = cycle(
        "+ straggler mitigation (backup writes)",
        stripe=1 * MiB, qd=4, zones=1, redundancy=True, concurrent_gc=True)
    rows["+ 4MiB stripes (fewer requests)"] = cycle(
        "+ 4MiB stripes (fewer requests)",
        stripe=4 * MiB, qd=4, zones=1, redundancy=True, concurrent_gc=True)
    rows["ablate: manifest via append (violates R1)"] = cycle(
        "ablate: manifest via append (violates R1)",
        stripe=4 * MiB, qd=4, zones=1, redundancy=True, concurrent_gc=True,
        manifest_op=OpType.APPEND)
    rows["ablate: serial GC (ignores Obs#12)"] = cycle(
        "ablate: serial GC (ignores Obs#12)",
        stripe=4 * MiB, qd=4, zones=1, redundancy=True, concurrent_gc=False)
    base = rows["naive: 4KiB appends QD1, serial GC, no redundancy"]
    best = min(rows.values())
    print(f"\nnaive -> best: {base:.2f}s -> {best:.2f}s "
          f"({base/best:.1f}x)")


if __name__ == "__main__":
    main()
